//! `dsanls shard` — pre-slice a dataset into an on-disk shard directory.
//!
//! ```text
//! dsanls shard --out DIR [--nodes N] [--input FILE] [--balance nnz]
//!              [--compress [--sketch subgaussian|countsketch] [--ratio R]]
//!              [--config FILE] [--key=value ...]
//! ```
//!
//! For generator-backed datasets the matrix is materialised **once**
//! (shard preparation is the only place the full matrix may exist) and
//! sliced into per-rank row-axis and column-axis block files plus a
//! manifest carrying the exact global `‖M‖²_F` and both partitions
//! ([`crate::data::shard`] documents the binary format). The operator
//! then copies each rank its two `rank-<r>.*.blk` files plus
//! `manifest.bin`, and starts workers with `--shards DIR` — every rank
//! reads only its blocks, so the deployable matrix size is bounded by the
//! *cluster's* memory, not one machine's.
//!
//! With `--input FILE` the matrix comes from an external COO text /
//! MatrixMarket-style file, streamed through the **chunked single-pass**
//! bucketing sharder ([`crate::data::ingest::shard_stream`]) — the full
//! matrix is *never* materialised, even here. Such manifests record a
//! `FILE:<stem>` dataset name; workers accept them with any dataset
//! config (the shards are authoritative), but `--verify-sim` is
//! unavailable (the simulator cannot regenerate an external file).
//!
//! `--balance nnz` cuts the **column axis** by cumulative stored-value
//! counts instead of equal column counts — the skew-aware layout for the
//! secure protocols, whose parties hold column blocks (a heavy party
//! stalls every synchronous consensus; see the imbalanced-workload
//! experiments). The manifest records the cuts; secure jobs pick them up
//! automatically, and the non-secure algorithms (which assume uniform
//! partitions) refuse balanced directories with a typed error.
//!
//! For generator-backed shards the manifest records dataset/seed/scale/
//! nodes; workers and `launch` refuse a directory that does not match
//! their config (preventing confusing bit-identity failures from stale
//! shards).
//!
//! `--compress` writes a **compressed** shard directory instead
//! ([`crate::data::compress`]): each rank gets one `rank-<r>.cblk` file
//! holding two fixed sketched views of its blocks — `M_{I_r:}·S_c` and
//! `(M_{:J_r})ᵀ·S_r` — at roughly `1/R` of the raw block footprint
//! (`--ratio R`, default 4). The sketching operators are *derived* from
//! the manifest's seed, never shipped; `--sketch` picks the family
//! (dense sub-Gaussian, default, or the sparse CountSketch). Workers
//! autodetect the v3 manifest and factorize the views directly — the raw
//! matrix never exists outside this command. Incompatible with `--input`
//! (streaming ingest never materialises the matrix to sketch) and with
//! `--balance nnz` (views have no per-column nnz).

use std::path::PathBuf;

use crate::coordinator;
use crate::data::compress;
use crate::data::ingest::{self, ShardBalance};
use crate::data::partition::{uniform_partition, weight_balanced_partition};
use crate::data::shard::{self, col_nnz_counts, ShardManifest};
use crate::error::{Context, Result};
use crate::linalg::Matrix;
use crate::sketch::SketchKind;

/// What `--compress` asked for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressSpec {
    /// Sketch family for the fixed views.
    pub kind: SketchKind,
    /// Target compression ratio `R` (views are ~`1/R` of the raw blocks).
    pub ratio: f64,
}

/// Options for one `dsanls shard` invocation.
pub struct ShardCliOptions {
    /// The resolved experiment configuration (dataset/seed/scale/nodes).
    pub cfg: crate::config::ExperimentConfig,
    /// Output directory for the manifest + block files.
    pub out: PathBuf,
    /// External matrix file to shard instead of the configured generator.
    pub input: Option<PathBuf>,
    /// Column-axis balance policy (`--balance nnz|uniform`).
    pub balance: ShardBalance,
    /// Write fixed sketched views instead of raw blocks (`--compress`).
    pub compress: Option<CompressSpec>,
}

/// Map the `--sketch` operand onto a [`SketchKind`]. The compressed data
/// plane supports the families whose fixed views keep the recovery bound
/// of the compressed-NMF analysis: dense sub-Gaussian and CountSketch.
fn parse_compress_sketch(name: &str) -> Result<SketchKind> {
    match name.to_ascii_lowercase().as_str() {
        "subgaussian" | "gaussian" | "g" => Ok(SketchKind::Gaussian),
        "countsketch" | "cs" => Ok(SketchKind::CountSketch),
        other => crate::bail!(
            "--sketch for compressed shards takes subgaussian or countsketch, got {other}"
        ),
    }
}

/// Parse `shard` CLI arguments.
pub fn parse_shard_args(args: &[String]) -> Result<ShardCliOptions> {
    let mut out: Option<PathBuf> = None;
    let mut input: Option<PathBuf> = None;
    let mut nodes_override = None;
    let mut balance = ShardBalance::Uniform;
    let mut compress = false;
    let mut sketch: Option<SketchKind> = None;
    let mut ratio: Option<f64> = None;
    let mut cfg_args: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--compress" => {
                compress = true;
                i += 1;
            }
            "--sketch" => {
                let v = args.get(i + 1).context("--sketch needs subgaussian|countsketch")?;
                sketch = Some(parse_compress_sketch(v)?);
                i += 2;
            }
            "--ratio" => {
                let v = args.get(i + 1).context("--ratio needs a number >= 1")?;
                ratio = Some(v.parse::<f64>().map_err(|e| crate::err!("--ratio {v}: {e}"))?);
                i += 2;
            }
            "--out" => {
                out = Some(PathBuf::from(args.get(i + 1).context("--out needs a DIR")?));
                i += 2;
            }
            "--input" => {
                input = Some(PathBuf::from(args.get(i + 1).context("--input needs a FILE")?));
                i += 2;
            }
            "--nodes" => {
                let v = args.get(i + 1).context("--nodes needs a number")?;
                nodes_override =
                    Some(v.parse::<usize>().map_err(|e| crate::err!("--nodes {v}: {e}"))?);
                i += 2;
            }
            "--balance" => {
                let v = args.get(i + 1).context("--balance needs nnz|uniform")?;
                balance = match v.as_str() {
                    "nnz" => ShardBalance::Nnz,
                    "uniform" => ShardBalance::Uniform,
                    other => crate::bail!("--balance takes nnz or uniform, got {other}"),
                };
                i += 2;
            }
            _ => {
                cfg_args.push(args[i].clone());
                i += 1;
            }
        }
    }
    let mut cfg = coordinator::parse_cli_config(&cfg_args).map_err(crate::error::Error::msg)?;
    if let Some(n) = nodes_override {
        cfg.nodes = n;
    }
    if cfg.nodes == 0 {
        crate::bail!("shard needs at least one node");
    }
    let out = out.context("shard needs --out DIR")?;
    let compress = if compress {
        if input.is_some() {
            crate::bail!(
                "--compress needs a generator-backed dataset — streaming ingest \
                 (--input) never materialises the matrix to sketch"
            );
        }
        if balance == ShardBalance::Nnz {
            crate::bail!(
                "--compress assumes uniform partitions — drop `--balance nnz` (the \
                 sketched views have no per-column nnz to balance)"
            );
        }
        Some(CompressSpec {
            kind: sketch.unwrap_or(SketchKind::Gaussian),
            ratio: ratio.unwrap_or(4.0),
        })
    } else {
        if sketch.is_some() || ratio.is_some() {
            crate::bail!("--sketch/--ratio apply to compressed shards — add --compress");
        }
        None
    };
    Ok(ShardCliOptions { cfg, out, input, balance, compress })
}

/// `dsanls shard` entry point: generate (or stream-ingest), slice, write,
/// report.
pub fn shard_main(args: &[String]) -> Result<()> {
    let opts = parse_shard_args(args)?;
    let cfg = &opts.cfg;
    if let Some(spec) = opts.compress {
        return compress_main(&opts, spec);
    }
    let (manifest, bytes) = match &opts.input {
        Some(path) => {
            // chunked single-pass bucketing: the full matrix is never built
            println!(
                "sharding matrix file {} for {} node(s) into {} (streaming{})",
                path.display(),
                cfg.nodes,
                opts.out.display(),
                if opts.balance == ShardBalance::Nnz { ", nnz-balanced columns" } else { "" }
            );
            ingest::shard_stream(path, &opts.out, cfg.nodes, opts.balance, cfg.seed, cfg.scale)?
        }
        None => {
            println!(
                "sharding {} (seed {}, scale {}) for {} node(s) into {}{}",
                cfg.dataset,
                cfg.seed,
                cfg.scale,
                cfg.nodes,
                opts.out.display(),
                if opts.balance == ShardBalance::Nnz { " (nnz-balanced columns)" } else { "" }
            );
            let m = coordinator::load_dataset(cfg);
            let col_part = match opts.balance {
                ShardBalance::Uniform => uniform_partition(m.cols(), cfg.nodes),
                ShardBalance::Nnz => {
                    weight_balanced_partition(&col_nnz_counts(&m), cfg.nodes)
                }
            };
            let manifest = ShardManifest {
                nodes: cfg.nodes,
                rows: m.rows(),
                cols: m.cols(),
                fro_sq: m.fro_sq(),
                seed: cfg.seed,
                scale: cfg.scale,
                dense: matches!(m, Matrix::Dense(_)),
                dataset: cfg.dataset.clone(),
                row_bounds: uniform_partition(m.rows(), cfg.nodes).bounds(),
                col_bounds: col_part.bounds(),
            };
            let bytes = shard::write_shard_dir(&opts.out, &m, &manifest)?;
            (manifest, bytes)
        }
    };
    println!(
        "wrote {}x{} as {} block file(s), {:.1} MiB total",
        manifest.rows,
        manifest.cols,
        2 * cfg.nodes,
        bytes as f64 / (1024.0 * 1024.0)
    );
    if manifest.is_balanced() {
        println!("column cuts (nnz-balanced): {:?}", manifest.col_bounds);
    }
    println!(
        "next: copy manifest.bin + rank-<r>.*.blk to each host, start workers with \
         `dsanls worker ... --shards {}` (see DEPLOYMENT.md)",
        opts.out.display()
    );
    Ok(())
}

/// `dsanls shard --compress`: materialise once, sketch each rank's blocks
/// into fixed views, write the v3 directory.
fn compress_main(opts: &ShardCliOptions, spec: CompressSpec) -> Result<()> {
    let cfg = &opts.cfg;
    println!(
        "compress-sharding {} (seed {}, scale {}) for {} node(s) into {} \
         ({:?} sketch, ratio {})",
        cfg.dataset,
        cfg.seed,
        cfg.scale,
        cfg.nodes,
        opts.out.display(),
        spec.kind,
        spec.ratio
    );
    let m = coordinator::load_dataset(cfg);
    let (d_r, d_c) = compress::ratio_dims(m.rows(), m.cols(), spec.ratio)?;
    let base = ShardManifest {
        nodes: cfg.nodes,
        rows: m.rows(),
        cols: m.cols(),
        fro_sq: m.fro_sq(),
        seed: cfg.seed,
        scale: cfg.scale,
        dense: matches!(m, Matrix::Dense(_)),
        dataset: cfg.dataset.clone(),
        row_bounds: uniform_partition(m.rows(), cfg.nodes).bounds(),
        col_bounds: uniform_partition(m.cols(), cfg.nodes).bounds(),
    };
    let (man, bytes) = compress::write_compressed_dir(&opts.out, &m, &base, spec.kind, d_r, d_c)?;
    println!(
        "wrote {}x{} as {} compressed view file(s) (d_r={}, d_c={}), {:.1} MiB total",
        man.base.rows,
        man.base.cols,
        cfg.nodes,
        man.d_r,
        man.d_c,
        bytes as f64 / (1024.0 * 1024.0)
    );
    println!(
        "next: copy manifest.bin + rank-<r>.cblk to each host, start workers with \
         `dsanls worker ... --shards {}` — workers autodetect the compressed format \
         (see DEPLOYMENT.md \"Compressed shards\")",
        opts.out.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_args_parse() {
        let args: Vec<String> = ["--out", "/tmp/s", "--nodes", "3", "--experiment.rank=4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let o = parse_shard_args(&args).unwrap();
        assert_eq!(o.cfg.nodes, 3);
        assert_eq!(o.cfg.rank, 4);
        assert_eq!(o.out, PathBuf::from("/tmp/s"));
        assert_eq!(o.balance, ShardBalance::Uniform);
        assert!(parse_shard_args(&["--nodes".into(), "2".into()]).is_err(), "--out required");

        let args: Vec<String> = ["--out", "/tmp/s", "--balance", "nnz"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(parse_shard_args(&args).unwrap().balance, ShardBalance::Nnz);
        let args: Vec<String> = ["--out", "/tmp/s", "--balance", "zipf"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(parse_shard_args(&args).is_err(), "unknown balance policy must error");
    }

    #[test]
    fn compress_args_parse_and_validate() {
        let mk = |args: &[&str]| {
            parse_shard_args(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
        };
        let o = mk(&["--out", "/tmp/s", "--compress"]).unwrap();
        assert_eq!(o.compress, Some(CompressSpec { kind: SketchKind::Gaussian, ratio: 4.0 }));
        let o = mk(&[
            "--out", "/tmp/s", "--compress", "--sketch", "countsketch", "--ratio", "8",
        ])
        .unwrap();
        assert_eq!(o.compress, Some(CompressSpec { kind: SketchKind::CountSketch, ratio: 8.0 }));
        // srht/subsample keep no recovery bound for fixed views — rejected
        assert!(mk(&["--out", "/tmp/s", "--compress", "--sketch", "srht"]).is_err());
        assert!(mk(&["--out", "/tmp/s", "--ratio", "4"]).is_err(), "--ratio needs --compress");
        assert!(mk(&["--out", "/tmp/s", "--sketch", "g"]).is_err(), "--sketch needs --compress");
        assert!(mk(&["--out", "/tmp/s", "--compress", "--balance", "nnz"]).is_err());
        assert!(mk(&["--out", "/tmp/s", "--compress", "--input", "/x.coo"]).is_err());
    }

    #[test]
    fn compress_main_writes_loadable_dir_raw_reader_refuses() {
        let dir = std::env::temp_dir()
            .join(format!("dsanls_shardcompress_{}", std::process::id()));
        let args: Vec<String> = [
            "--out",
            dir.to_str().unwrap(),
            "--nodes",
            "2",
            "--experiment.dataset=face",
            "--experiment.scale=0.05",
            "--compress",
            "--ratio",
            "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        shard_main(&args).unwrap();
        let man = compress::read_compressed_manifest(&dir).unwrap();
        assert_eq!(man.base.nodes, 2);
        assert_eq!(man.kind, SketchKind::Gaussian);
        let (block, _) = crate::data::CompressedBlock::load(&dir, 1).unwrap();
        assert_eq!(block.d_c(), man.d_c);
        assert_eq!(block.d_r(), man.d_r);
        // the raw reader must refuse the v3 directory with a typed message
        let err = shard::read_manifest(&dir).unwrap_err().to_string();
        assert!(err.contains("compressed"), "raw reader should name the format: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_from_input_file_writes_loadable_dir() {
        let base = std::env::temp_dir()
            .join(format!("dsanls_shardinput_{}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        let coo = base.join("tiny.coo");
        // 4x3, 5 entries — plenty for a 2-node shard set
        std::fs::write(&coo, "4 3 5\n0 0 1.0\n1 1 2.0\n2 2 3.0\n3 0 4.0\n3 2 0.5\n").unwrap();
        let dir = base.join("shards");
        let args: Vec<String> = [
            "--out",
            dir.to_str().unwrap(),
            "--input",
            coo.to_str().unwrap(),
            "--nodes",
            "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        shard_main(&args).unwrap();
        let manifest = shard::read_manifest(&dir).unwrap();
        assert_eq!(manifest.nodes, 2);
        assert_eq!(manifest.dataset, "FILE:tiny");
        assert!(shard::is_file_dataset(&manifest.dataset));
        assert_eq!((manifest.rows, manifest.cols), (4, 3));
        let (data, _) = crate::data::shard::NodeData::load(&dir, 0, true, true).unwrap();
        assert_eq!(data.fro_sq().to_bits(), manifest.fro_sq.to_bits());
        assert!(data.nnz() > 0);
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn shard_from_malformed_input_errors() {
        let base = std::env::temp_dir()
            .join(format!("dsanls_shardbad_{}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        let coo = base.join("bad.coo");
        std::fs::write(&coo, "4 3 5\n0 0 1.0\n9 9 2.0\n").unwrap(); // oob + truncated
        let dir = base.join("shards");
        let args: Vec<String> =
            ["--out", dir.to_str().unwrap(), "--input", coo.to_str().unwrap(), "--nodes", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = shard_main(&args).unwrap_err();
        assert!(err.to_string().contains("line"), "error should name the line: {err}");
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn shard_main_writes_loadable_dir() {
        let dir = std::env::temp_dir()
            .join(format!("dsanls_shardcli_{}", std::process::id()));
        let args: Vec<String> = [
            "--out",
            dir.to_str().unwrap(),
            "--nodes",
            "2",
            "--experiment.dataset=face",
            "--experiment.scale=0.05",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        shard_main(&args).unwrap();
        let manifest = shard::read_manifest(&dir).unwrap();
        assert_eq!(manifest.nodes, 2);
        assert_eq!(manifest.dataset, "FACE");
        let (data, _) = crate::data::shard::NodeData::load(&dir, 1, true, true).unwrap();
        assert_eq!(data.rows, manifest.rows);
        assert!(data.m_rows.is_some() && data.m_cols.is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
