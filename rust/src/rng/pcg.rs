//! PCG64 (XSL-RR 128/64) — a small, fast, statistically strong PRNG.
//!
//! Hand-rolled because the environment vendors no `rand` crate; the paper's
//! shared-seed trick (Sec. 3.3) only needs *determinism across nodes*, which
//! PCG gives us with a 128-bit state and explicit stream selection.

const MULTIPLIER: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG-XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    /// Stream increment; must be odd.
    inc: u128,
}

impl Pcg64 {
    /// Create a generator from a 128-bit seed and stream id.
    pub fn new(seed: u128, stream: u128) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(MULTIPLIER).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(MULTIPLIER).wrapping_add(inc);
        rng
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULTIPLIER).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u32 << 24) as f32
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Fisher–Yates sample of `k` distinct indices from [0, n) (order is
    /// random). Used for subsampling sketch matrices (Sec. 3.4: "each column
    /// ... uniformly sampled from {e₁..e_n} without replacement").
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct values from {n}");
        // Partial Fisher–Yates over an index map: O(k) memory via hashmap-free
        // trick is overkill here (n is a matrix dimension); use a full vec.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Random permutation of [0, n).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        self.sample_without_replacement(n, n)
    }

    /// Rademacher ±1 sample.
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(123, 0);
        let mut b = Pcg64::new(123, 0);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_independent() {
        let mut a = Pcg64::new(123, 0);
        let mut b = Pcg64::new(123, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_uniformity() {
        let mut r = Pcg64::new(7, 3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Pcg64::new(9, 1);
        let s = r.sample_without_replacement(100, 40);
        assert_eq!(s.len(), 40);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40, "duplicates in sample");
        assert!(sorted.iter().all(|&x| x < 100));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg64::new(11, 2);
        let mut p = r.permutation(50);
        p.sort_unstable();
        assert_eq!(p, (0..50).collect::<Vec<_>>());
    }
}
