//! Deterministic, seed-derivable random number generation.
//!
//! The paper's DSANLS algorithm (Sec. 3.3) avoids broadcasting the sketch
//! matrix `Sᵗ` by having **every node regenerate the identical matrix from a
//! shared seed**: "we only need to broadcast the random seed, which is just
//! an integer, at the beginning of the whole program".
//!
//! [`StreamRng::for_iteration`] implements exactly that contract: any node
//! holding the shared seed derives the same generator for a given
//! `(iteration, role)` pair, with streams for distinct pairs statistically
//! independent (SplitMix64 stream-splitting into PCG64).

mod pcg;

pub use pcg::Pcg64;

/// Role tags for deriving independent random streams from the shared seed.
///
/// `SketchU`/`SketchV` correspond to the paper's `Sᵗ` and `S'ᵗ` matrices
/// (Alg. 2 lines 4 and 10); `Init` seeds factor initialisation; `Data`
/// seeds synthetic dataset generation; `Noise` is free for tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Init = 1,
    SketchU = 2,
    SketchV = 3,
    Data = 4,
    Noise = 5,
}

/// SplitMix64: used to expand a 64-bit seed into well-mixed stream keys.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A shared-seed stream factory. Every cluster node constructs one from the
/// broadcast seed; [`StreamRng::for_iteration`] then yields bit-identical
/// generators on every node — the communication-free sketch trick.
#[derive(Debug, Clone, Copy)]
pub struct StreamRng {
    seed: u64,
}

impl StreamRng {
    pub fn new(seed: u64) -> Self {
        StreamRng { seed }
    }

    /// The shared seed (what the leader broadcasts once).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Derive the generator for `(iteration, role)`. Deterministic:
    /// identical on every node holding the same seed.
    pub fn for_iteration(&self, iteration: u64, role: Role) -> Pcg64 {
        let mut s = self
            .seed
            .wrapping_add(iteration.wrapping_mul(0xA076_1D64_78BD_642F))
            .wrapping_add((role as u64).wrapping_mul(0xE703_7ED1_A0B4_28DB));
        let lo = splitmix64(&mut s);
        let hi = splitmix64(&mut s);
        Pcg64::new(((hi as u128) << 64) | lo as u128, role as u128)
    }

    /// A per-node private stream (for node-local decisions that must NOT be
    /// shared, e.g. asynchronous jitter in the Asyn-* protocols).
    pub fn for_node(&self, node: usize, salt: u64) -> Pcg64 {
        let mut s = self
            .seed
            .wrapping_add((node as u64).wrapping_mul(0x9E6C_63D0_876A_9B55))
            .wrapping_add(salt);
        let lo = splitmix64(&mut s);
        let hi = splitmix64(&mut s);
        Pcg64::new(((hi as u128) << 64) | lo as u128, node as u128)
    }
}

/// Standard-normal sampling via the Box–Muller transform, buffering the
/// second variate. Used for Gaussian sketch matrices (Sec. 3.4) and data
/// synthesis.
#[derive(Debug, Clone)]
pub struct Gaussian {
    rng: Pcg64,
    spare: Option<f64>,
}

impl Gaussian {
    pub fn new(rng: Pcg64) -> Self {
        Gaussian { rng, spare: None }
    }

    /// One N(0, 1) sample.
    pub fn sample(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        // Box–Muller on (0,1]-uniform variates; u > 0 guaranteed below.
        let u = (self.rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        let v = (self.rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        let u = if u <= f64::MIN_POSITIVE { f64::MIN_POSITIVE } else { u };
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// One N(0, sigma²) sample as f32.
    pub fn sample_f32(&mut self, sigma: f32) -> f32 {
        (self.sample() as f32) * sigma
    }

    /// Fill a slice with N(0, sigma²) f32 samples.
    pub fn fill(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = self.sample_f32(sigma);
        }
    }

    /// Fill from a borrowed generator without constructing a `Gaussian`.
    ///
    /// §Perf: one PRNG draw per pair + f32 transcendentals (the sketch only
    /// needs f32 variates; f64 ln/sin/cos dominated sketch generation —
    /// 10.9 ms → ~3 ms for a 2450×245 sketch). The previous version also
    /// cloned the rng and re-drew every variate to advance the caller's
    /// stream — twice the work.
    pub fn fill_from(rng: &mut Pcg64, out: &mut [f32], sigma: f32) {
        let mut i = 0;
        while i + 1 < out.len() {
            let bits = rng.next_u64();
            let u = (((bits >> 40) as u32) as f32 / (1u32 << 24) as f32).max(1e-12);
            let v = ((bits & 0xFF_FFFF) as u32) as f32 / (1u32 << 24) as f32;
            let r = (-2.0 * u.ln()).sqrt() * sigma;
            let (s, c) = (2.0 * std::f32::consts::PI * v).sin_cos();
            out[i] = r * c;
            out[i + 1] = r * s;
            i += 2;
        }
        if i < out.len() {
            let bits = rng.next_u64();
            let u = (((bits >> 40) as u32) as f32 / (1u32 << 24) as f32).max(1e-12);
            let v = ((bits & 0xFF_FFFF) as u32) as f32 / (1u32 << 24) as f32;
            out[i] = (-2.0 * u.ln()).sqrt() * (2.0 * std::f32::consts::PI * v).cos() * sigma;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_stream() {
        let a = StreamRng::new(42).for_iteration(7, Role::SketchU);
        let b = StreamRng::new(42).for_iteration(7, Role::SketchU);
        let mut a = a;
        let mut b = b;
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_roles_differ() {
        let mut a = StreamRng::new(42).for_iteration(7, Role::SketchU);
        let mut b = StreamRng::new(42).for_iteration(7, Role::SketchV);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams for different roles must diverge");
    }

    #[test]
    fn different_iterations_differ() {
        let mut a = StreamRng::new(42).for_iteration(7, Role::SketchU);
        let mut b = StreamRng::new(42).for_iteration(8, Role::SketchU);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gaussian_moments() {
        let mut g = Gaussian::new(StreamRng::new(1).for_iteration(0, Role::Noise));
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = g.sample();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn uniform_range() {
        let mut r = StreamRng::new(3).for_node(2, 0);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            let k = r.below(17);
            assert!(k < 17);
        }
    }
}
