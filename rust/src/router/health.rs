//! Per-replica health bookkeeping for the router.
//!
//! The router learns health passively, from the requests it already
//! sends: a transport failure marks the replica *down* for a cooldown
//! window and routes around it; the next request after the window
//! retries it (and one success marks it fully up again). No separate
//! ping thread — a replica that answers queries is healthy by
//! definition, and one that doesn't gets probed at most once per
//! cooldown instead of hammered.

use std::sync::Mutex;
use std::time::{Duration, Instant};

#[derive(Debug, Default)]
struct State {
    consecutive_failures: u32,
    down_until: Option<Instant>,
}

/// Passive health state for one replica.
#[derive(Debug, Default)]
pub struct ReplicaHealth {
    state: Mutex<State>,
}

impl ReplicaHealth {
    /// A fresh, presumed-healthy replica.
    pub fn new() -> ReplicaHealth {
        ReplicaHealth::default()
    }

    /// Should the router send this replica traffic right now? `true`
    /// when never failed, recovered, or the cooldown has elapsed (the
    /// elapsed case is the single retry probe).
    pub fn available(&self) -> bool {
        let s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        match s.down_until {
            Some(t) => Instant::now() >= t,
            None => true,
        }
    }

    /// A request to this replica succeeded: clear the failure streak.
    pub fn record_success(&self) {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.consecutive_failures = 0;
        s.down_until = None;
    }

    /// A request failed at the transport level: extend the down window.
    pub fn record_failure(&self, cooldown: Duration) {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.consecutive_failures = s.consecutive_failures.saturating_add(1);
        s.down_until = Some(Instant::now() + cooldown);
    }

    /// Consecutive transport failures since the last success.
    pub fn failures(&self) -> u32 {
        self.state.lock().unwrap_or_else(|p| p.into_inner()).consecutive_failures
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_marks_down_until_cooldown_elapses() {
        let h = ReplicaHealth::new();
        assert!(h.available());
        h.record_failure(Duration::from_millis(40));
        assert!(!h.available());
        assert_eq!(h.failures(), 1);
        h.record_failure(Duration::from_millis(40));
        assert_eq!(h.failures(), 2);
        std::thread::sleep(Duration::from_millis(60));
        // cooldown elapsed → eligible for one retry probe
        assert!(h.available());
    }

    #[test]
    fn success_resets_the_streak() {
        let h = ReplicaHealth::new();
        h.record_failure(Duration::from_secs(3600));
        assert!(!h.available());
        h.record_success();
        assert!(h.available());
        assert_eq!(h.failures(), 0);
    }
}
