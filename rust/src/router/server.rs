//! The `dsanls route` front-end server.
//!
//! Speaks the exact serving wire protocol on both sides: clients connect
//! with plain [`ServeClient`] / `dsanls query` as if the router were a
//! single server, and the router forwards each query to a replica chosen
//! by the consistent-hash [`HashRing`] through that replica's
//! [`ReplicaPool`]. Keyed queries (top-k, reconstruct, fold-ins) hash to
//! one owner and fail over along the ring when it is down; `Stats` fans
//! out to every replica and returns an aggregated snapshot; `Reload`
//! broadcasts the hot-swap and fails loudly if ANY replica refuses — a
//! rolling update that only half-took is an incident, not a success.
//!
//! Topology mirrors [`crate::serve::server`] minus the batcher: one
//! acceptor thread plus one thread per client connection, each
//! forwarding synchronously (the replicas own the batching).

use std::io::BufWriter;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Context, Result};
use crate::metrics::JsonValue;
use crate::router::pool::ReplicaPool;
use crate::router::ring::{fnv1a, HashRing};
use crate::serve::protocol::{self, Query, Reply};
use crate::transport::wire;

/// Tuning knobs for [`route`].
#[derive(Debug, Clone)]
pub struct RouteOptions {
    /// Virtual points per replica on the hash ring.
    pub vnodes: usize,
    /// Read/write deadline on router→replica sockets.
    pub io_timeout: Duration,
    /// How long a transport-failed replica stays routed-around before
    /// the next request probes it again.
    pub cooldown: Duration,
}

impl Default for RouteOptions {
    fn default() -> Self {
        RouteOptions {
            vnodes: 64,
            io_timeout: Duration::from_secs(2),
            cooldown: Duration::from_secs(1),
        }
    }
}

/// Router-side counters (per-replica health lives in the pools).
#[derive(Debug)]
struct RouterMetrics {
    /// Queries forwarded (including broadcasts, counted once each).
    routed: AtomicU64,
    /// Keyed queries that had to skip at least one replica.
    failovers: AtomicU64,
    /// Queries the router itself failed (no replica reachable, decode
    /// errors) — replica-side `Reply::Error`s are the replicas' stats.
    errors: AtomicU64,
    started: Instant,
}

struct RouterShared {
    ring: HashRing,
    pools: Vec<ReplicaPool>,
    opts: RouteOptions,
    metrics: RouterMetrics,
    stop: AtomicBool,
}

impl std::fmt::Debug for RouterShared {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RouterShared({} replicas)", self.pools.len())
    }
}

/// The ring key for a query, `None` for broadcasts (`Stats`, `Reload`).
///
/// Score queries key on their **first user id** so one client batch
/// stays on one replica (one coalesced GEMM there, and repeat queries
/// for a user hit the same replica's warm path). Fold-ins key on the
/// canonical sorted row — the identical row always routes to the same
/// replica, which is what makes the per-replica fold-in caches
/// effective behind a router; a side byte keeps a user row and an item
/// column with equal entries from colliding.
fn query_key(q: &Query) -> Option<u64> {
    fn fold_key(side: u8, entries: &[(u64, f32)]) -> u64 {
        let mut canon: Vec<(u64, u32)> =
            entries.iter().map(|&(i, v)| (i, v.to_bits())).collect();
        canon.sort_unstable();
        let mut bytes = Vec::with_capacity(1 + canon.len() * 12);
        bytes.push(side);
        for (i, v) in canon {
            bytes.extend_from_slice(&i.to_le_bytes());
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        fnv1a(&bytes)
    }
    match q {
        Query::TopK { users, .. } | Query::Reconstruct { users } => {
            Some(fnv1a(&users.first().copied().unwrap_or(0).to_le_bytes()))
        }
        Query::FoldIn { entries, .. } => Some(fold_key(0, entries)),
        Query::FoldInItem { entries, .. } => Some(fold_key(1, entries)),
        Query::Stats | Query::Reload => None,
    }
}

/// Forward a keyed query to its ring owner, failing over clockwise.
/// Returns the reply plus the backing replica's generation.
fn forward_keyed(shared: &RouterShared, key: u64, q: &Query, order: &mut Vec<usize>) -> (Reply, u64) {
    shared.ring.order(key, order);
    // prefer replicas not in a cooldown window; if every one is marked
    // down, probe them all anyway — routing into a possibly-dead replica
    // beats refusing a query that might have succeeded
    let any_up = order.iter().any(|&i| shared.pools[i].health.available());
    let mut skipped = 0u64;
    for &idx in order.iter() {
        let pool = &shared.pools[idx];
        if any_up && !pool.health.available() {
            continue;
        }
        match pool.request(q, shared.opts.io_timeout, shared.opts.cooldown) {
            Ok((reply, generation)) => {
                if skipped > 0 {
                    shared.metrics.failovers.fetch_add(1, Ordering::Relaxed);
                }
                return (reply, generation);
            }
            Err(_) => skipped += 1, // pool already marked the replica down
        }
    }
    shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
    (
        Reply::Error(format!(
            "no replica reachable for this query ({} tried)",
            shared.pools.len()
        )),
        0,
    )
}

/// Sum these per-replica counters into the aggregated stats object.
const SUMMED: &[&str] = &[
    "queries",
    "errors",
    "batches",
    "rows_scored",
    "fold_in_solves",
    "swaps",
    "cache_hits",
    "cache_misses",
    "cache_len",
];

/// Fan `Stats` out to every replica and aggregate: summed throughput
/// counters, the **minimum** generation (the fleet has converged on a
/// rolling update exactly when min == max, and min is the conservative
/// answer to "what is everyone serving at least?"), a per-replica
/// breakdown, and the router's own counters.
fn stats_reply(shared: &RouterShared) -> (Reply, u64) {
    let mut sums = vec![0.0f64; SUMMED.len()];
    let mut min_generation: Option<f64> = None;
    let mut per_replica = Vec::with_capacity(shared.pools.len());
    let mut reachable = 0usize;
    for pool in &shared.pools {
        let entry = match pool.request(&Query::Stats, shared.opts.io_timeout, shared.opts.cooldown)
        {
            Ok((Reply::Stats(text), _)) => match JsonValue::parse(&text) {
                Ok(stats) => {
                    reachable += 1;
                    for (slot, key) in sums.iter_mut().zip(SUMMED) {
                        if let Some(v) = stats.get(key).and_then(JsonValue::as_f64) {
                            *slot += v;
                        }
                    }
                    if let Some(g) = stats.get("generation").and_then(JsonValue::as_f64) {
                        min_generation =
                            Some(min_generation.map_or(g, |m: f64| m.min(g)));
                    }
                    stats
                }
                Err(e) => JsonValue::String(format!("unparseable stats: {e}")),
            },
            Ok((other, _)) => JsonValue::String(format!("unexpected stats reply {other:?}")),
            Err(e) => JsonValue::String(format!("unreachable: {e}")),
        };
        per_replica.push(JsonValue::Object(vec![
            ("addr".into(), JsonValue::String(pool.addr().to_string())),
            ("stats".into(), entry),
        ]));
    }
    if reachable == 0 {
        shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
        return (Reply::Error("stats: no replica reachable".into()), 0);
    }
    let generation = min_generation.unwrap_or(0.0);
    let up = shared.pools.iter().filter(|p| p.health.available()).count();
    let mut obj: Vec<(String, JsonValue)> = sums
        .iter()
        .zip(SUMMED)
        .map(|(&v, &k)| (k.to_string(), JsonValue::Number(v)))
        .collect();
    obj.push(("generation".into(), JsonValue::Number(generation)));
    obj.push(("replicas".into(), JsonValue::Array(per_replica)));
    obj.push((
        "router".into(),
        JsonValue::Object(vec![
            ("replicas".into(), JsonValue::Number(shared.pools.len() as f64)),
            ("up".into(), JsonValue::Number(up as f64)),
            (
                "routed".into(),
                JsonValue::Number(shared.metrics.routed.load(Ordering::Relaxed) as f64),
            ),
            (
                "failovers".into(),
                JsonValue::Number(shared.metrics.failovers.load(Ordering::Relaxed) as f64),
            ),
            (
                "errors".into(),
                JsonValue::Number(shared.metrics.errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "uptime_s".into(),
                JsonValue::Number(shared.metrics.started.elapsed().as_secs_f64()),
            ),
        ]),
    ));
    (Reply::Stats(JsonValue::Object(obj).to_string()), generation as u64)
}

/// Broadcast `Reload` to every replica. All-or-error: a rolling update
/// that reached only part of the fleet must surface as a failure so the
/// operator re-runs it, not as a silent split-generation fleet.
fn reload_reply(shared: &RouterShared) -> (Reply, u64) {
    let mut min_generation = u64::MAX;
    let mut min_iteration = u64::MAX;
    for pool in &shared.pools {
        match pool.request(&Query::Reload, shared.opts.io_timeout, shared.opts.cooldown) {
            Ok((Reply::Reload { generation, iteration }, _)) => {
                min_generation = min_generation.min(generation);
                min_iteration = min_iteration.min(iteration);
            }
            Ok((Reply::Error(msg), _)) => {
                shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                return (
                    Reply::Error(format!("reload refused by replica {}: {msg}", pool.addr())),
                    0,
                );
            }
            Ok((other, _)) => {
                shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                return (
                    Reply::Error(format!(
                        "unexpected reload reply {other:?} from replica {}",
                        pool.addr()
                    )),
                    0,
                );
            }
            Err(e) => {
                shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                return (
                    Reply::Error(format!("reload failed: replica {} unreachable: {e}", pool.addr())),
                    0,
                );
            }
        }
    }
    (Reply::Reload { generation: min_generation, iteration: min_iteration }, min_generation)
}

fn connection_loop(shared: Arc<RouterShared>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let mut reader = match stream.try_clone() {
        Ok(s) => std::io::BufReader::new(s),
        Err(_) => return,
    };
    if wire::read_preamble(&mut reader).is_err() {
        return;
    }
    let mut writer = BufWriter::new(stream);
    if wire::write_preamble(&mut writer, 0).is_err() {
        return;
    }
    let mut order = Vec::new();
    loop {
        let frame = match wire::read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return, // client hung up
        };
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let (reply, generation) = if frame.kind != wire::FrameKind::Request {
            shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
            (
                Reply::Error(format!("unexpected {:?} frame on a router connection", frame.kind)),
                0,
            )
        } else {
            match protocol::decode_query(&frame.payload) {
                Ok(q) => {
                    shared.metrics.routed.fetch_add(1, Ordering::Relaxed);
                    match query_key(&q) {
                        Some(key) => forward_keyed(&shared, key, &q, &mut order),
                        None => match q {
                            Query::Stats => stats_reply(&shared),
                            Query::Reload => reload_reply(&shared),
                            _ => unreachable!("only broadcasts key to None"),
                        },
                    }
                }
                Err(e) => {
                    shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    (Reply::Error(format!("router: {e}")), 0)
                }
            }
        };
        let payload = protocol::encode_reply(&reply);
        if wire::write_frame_parts(
            &mut writer,
            protocol::RESPONSE,
            frame.tag,
            generation as f64,
            &payload,
        )
        .is_err()
        {
            return;
        }
    }
}

/// A running router. Dropping the handle shuts it down.
#[derive(Debug)]
pub struct RouterHandle {
    addr: SocketAddr,
    shared: Arc<RouterShared>,
    accept: Option<JoinHandle<()>>,
}

impl RouterHandle {
    /// The address the router actually bound (port resolved for `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the router-side counters (not the replicas' stats —
    /// those aggregate through a `Stats` query).
    pub fn metrics_json(&self) -> JsonValue {
        let m = &self.shared.metrics;
        let up = self.shared.pools.iter().filter(|p| p.health.available()).count();
        JsonValue::Object(vec![
            ("replicas".into(), JsonValue::Number(self.shared.pools.len() as f64)),
            ("up".into(), JsonValue::Number(up as f64)),
            ("routed".into(), JsonValue::Number(m.routed.load(Ordering::Relaxed) as f64)),
            (
                "failovers".into(),
                JsonValue::Number(m.failovers.load(Ordering::Relaxed) as f64),
            ),
            ("errors".into(), JsonValue::Number(m.errors.load(Ordering::Relaxed) as f64)),
            ("uptime_s".into(), JsonValue::Number(m.started.elapsed().as_secs_f64())),
        ])
    }

    /// Stop accepting and join the acceptor. Idempotent; also runs on
    /// drop. Live client connections exit on their next frame.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        let poke = if self.addr.ip().is_unspecified() {
            SocketAddr::from(([127, 0, 0, 1], self.addr.port()))
        } else {
            self.addr
        };
        let _ = TcpStream::connect_timeout(&poke, Duration::from_millis(250));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bind `addr` and route serving queries across `replicas` until the
/// returned handle is shut down or dropped. Replicas are dialed lazily —
/// one may be down at startup and pick traffic up when it returns.
pub fn route(addr: &str, replicas: &[String], opts: RouteOptions) -> Result<RouterHandle> {
    let ring = HashRing::new(replicas, opts.vnodes)?;
    let pools = replicas.iter().map(|a| ReplicaPool::new(a.clone())).collect();
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding router listener on {addr}"))?;
    let bound = listener.local_addr().context("resolving router listener address")?;
    let shared = Arc::new(RouterShared {
        ring,
        pools,
        opts,
        metrics: RouterMetrics {
            routed: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            started: Instant::now(),
        },
        stop: AtomicBool::new(false),
    });

    let accept_shared = shared.clone();
    let accept = std::thread::Builder::new()
        .name("dsanls-route-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if accept_shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    let conn_shared = accept_shared.clone();
                    let _ = std::thread::Builder::new()
                        .name("dsanls-route-conn".into())
                        .spawn(move || connection_loop(conn_shared, stream));
                }
            }
        })
        .context("spawning router accept thread")?;

    Ok(RouterHandle { addr: bound, shared, accept: Some(accept) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keyed_queries_are_stable_and_broadcasts_are_not_keyed() {
        let topk = Query::TopK { users: vec![42, 7], n: 5 };
        // same leading user → same key, whatever trails it
        assert_eq!(query_key(&topk), query_key(&Query::Reconstruct { users: vec![42] }));
        // fold-in keys are order-insensitive …
        let a = Query::FoldIn { entries: vec![(3, 1.0), (9, 2.0)], n: 0 };
        let b = Query::FoldIn { entries: vec![(9, 2.0), (3, 1.0)], n: 4 };
        assert_eq!(query_key(&a), query_key(&b));
        // … and side-disambiguated from item fold-ins of the same entries
        let item = Query::FoldInItem { entries: vec![(3, 1.0), (9, 2.0)], n: 0 };
        assert_ne!(query_key(&a), query_key(&item));
        assert_eq!(query_key(&Query::Stats), None);
        assert_eq!(query_key(&Query::Reload), None);
    }
}
