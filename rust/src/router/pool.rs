//! Per-replica connection pool.
//!
//! Each replica gets a small stack of idle [`ServeClient`] connections;
//! a forwarded query checks one out (or dials fresh), runs, and checks
//! it back in on success. A pooled connection that fails gets ONE fresh
//! redial before the replica is declared down — a stale socket from an
//! earlier replica restart must not read as an outage.

use std::sync::Mutex;
use std::time::Duration;

use crate::error::Result;
use crate::router::health::ReplicaHealth;
use crate::serve::protocol::{Query, Reply};
use crate::serve::ServeClient;

/// Idle connections kept per replica (beyond this, finished connections
/// are dropped instead of pooled).
const POOL_CAP: usize = 8;

/// Connection pool + health state for one replica address.
#[derive(Debug)]
pub struct ReplicaPool {
    addr: String,
    idle: Mutex<Vec<ServeClient>>,
    /// Passive health (the router consults this before routing here).
    pub health: ReplicaHealth,
}

impl ReplicaPool {
    /// An empty pool for `addr`; connections are dialed lazily.
    pub fn new(addr: String) -> ReplicaPool {
        ReplicaPool { addr, idle: Mutex::new(Vec::new()), health: ReplicaHealth::new() }
    }

    /// The replica address this pool fronts.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn check_out(&self) -> Option<ServeClient> {
        self.idle.lock().unwrap_or_else(|p| p.into_inner()).pop()
    }

    fn check_in(&self, client: ServeClient) {
        let mut idle = self.idle.lock().unwrap_or_else(|p| p.into_inner());
        if idle.len() < POOL_CAP {
            idle.push(client);
        }
    }

    /// Forward `q` to this replica. `Ok((reply, generation))` carries the
    /// replica's reply — **including** [`Reply::Error`], which means the
    /// replica answered and the router must NOT fail over — plus the
    /// model generation it advertised. `Err` means the replica is
    /// unreachable after a pooled attempt and a fresh redial; the health
    /// state is already marked down for `cooldown`.
    pub fn request(
        &self,
        q: &Query,
        timeout: Duration,
        cooldown: Duration,
    ) -> Result<(Reply, u64)> {
        // attempt 1: a pooled connection, if any survives from earlier
        if let Some(mut client) = self.check_out() {
            if let Ok(reply) = client.query_reply(q) {
                let generation = client.generation();
                self.check_in(client);
                self.health.record_success();
                return Ok((reply, generation));
            }
            // stale socket (replica restarted, idle timeout, …): fall
            // through to a fresh dial before judging the replica down
        }
        // attempt 2: dial fresh with the router's I/O deadline
        match ServeClient::connect_with(&self.addr, Some(timeout))
            .and_then(|mut client| client.query_reply(q).map(|reply| (client, reply)))
        {
            Ok((client, reply)) => {
                let generation = client.generation();
                self.check_in(client);
                self.health.record_success();
                Ok((reply, generation))
            }
            Err(e) => {
                self.health.record_failure(cooldown);
                Err(e)
            }
        }
    }
}
