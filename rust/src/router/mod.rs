//! Replicated serving tier: a consistent-hash router over `dsanls
//! serve` replicas.
//!
//! Training scales writes across ranks; this subsystem scales the
//! **read** path the same way. `dsanls route --replicas host:port,...
//! --bind ADDR` fronts any number of serving replicas behind one
//! address speaking the unchanged wire protocol — clients keep using
//! plain `dsanls query` / [`crate::serve::ServeClient`] and cannot tell
//! a router from a single server.
//!
//! * [`ring`] — the consistent-hash ring (FNV-1a, virtual nodes):
//!   keyed queries land on a stable owner, and removing a replica only
//!   moves that replica's keys, so surviving fold-in caches stay hot
//!   through a failover.
//! * [`pool`] — per-replica connection pools reusing
//!   [`crate::serve::ServeClient`] with I/O deadlines, retrying once on
//!   a fresh socket before declaring a replica down.
//! * [`health`] — passive cooldown-based health: a transport failure
//!   routes the replica around for a window; the next request after the
//!   window probes it, and one success restores it.
//! * [`server`] — the router itself: keyed forwarding with ring-order
//!   failover, aggregated `Stats` fan-out, all-or-error `Reload`
//!   broadcast for rolling hot-swaps across the fleet.
//!
//! CLI surface: `dsanls route`
//! ([`crate::coordinator::route_cli`]; walkthrough in DEPLOYMENT.md
//! §Replicated serving).

#![warn(missing_docs)]

pub mod health;
pub mod pool;
pub mod ring;
pub mod server;

pub use ring::HashRing;
pub use server::{route, RouteOptions, RouterHandle};
