//! Consistent-hash ring over replica addresses.
//!
//! Each replica contributes [`HashRing::vnodes`]-many virtual points
//! hashed from `"{addr}#{v}"`; a query key routes to the owner of the
//! first point clockwise from its hash. The classic consistent-hashing
//! property follows: removing one replica reassigns only the keys that
//! replica owned (its points vanish; every other point keeps its
//! position), so a failover never reshuffles traffic that was already
//! landing on healthy replicas — their fold-in caches stay hot.
//!
//! The hash is FNV-1a (64-bit): tiny, dependency-free, and plenty
//! uniform for spreading vnode points — this is load balancing, not
//! cryptography.

use crate::error::Result;

/// 64-bit FNV-1a over `bytes` — the ring's point and key hash.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A consistent-hash ring mapping `u64` keys to replica indices.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(point, replica index)` sorted by point.
    points: Vec<(u64, usize)>,
    replicas: usize,
}

impl HashRing {
    /// Build a ring over `replicas` (addresses or any distinct labels)
    /// with `vnodes` virtual points each. Errors on an empty replica set.
    pub fn new(replicas: &[String], vnodes: usize) -> Result<HashRing> {
        if replicas.is_empty() {
            crate::bail!("consistent-hash ring needs at least one replica");
        }
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(replicas.len() * vnodes);
        for (idx, addr) in replicas.iter().enumerate() {
            for v in 0..vnodes {
                points.push((fnv1a(format!("{addr}#{v}").as_bytes()), idx));
            }
        }
        // ties (astronomically unlikely) break by replica index so the
        // layout is deterministic for a given replica list
        points.sort_unstable();
        Ok(HashRing { points, replicas: replicas.len() })
    }

    /// Number of replicas the ring was built over.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// The replica index owning `key`: the first vnode point clockwise
    /// from `key`'s position (wrapping past the top of the ring).
    pub fn route(&self, key: u64) -> usize {
        let i = self.points.partition_point(|&(p, _)| p < key);
        self.points[if i == self.points.len() { 0 } else { i }].1
    }

    /// Fill `out` with every replica index in ring order starting at
    /// `key`'s owner — the failover sequence: try `out[0]`, then `out[1]`,
    /// … Each replica appears exactly once.
    pub fn order(&self, key: u64, out: &mut Vec<usize>) {
        out.clear();
        let start = self.points.partition_point(|&(p, _)| p < key);
        for step in 0..self.points.len() {
            let idx = self.points[(start + step) % self.points.len()].1;
            if !out.contains(&idx) {
                out.push(idx);
                if out.len() == self.replicas {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(list: &[&str]) -> Vec<String> {
        list.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn empty_ring_is_refused() {
        assert!(HashRing::new(&[], 64).is_err());
    }

    #[test]
    fn distribution_is_roughly_uniform() {
        let ring =
            HashRing::new(&addrs(&["10.0.0.1:7878", "10.0.0.2:7878", "10.0.0.3:7878"]), 64)
                .unwrap();
        let mut counts = [0usize; 3];
        for key in 0..10_000u64 {
            counts[ring.route(fnv1a(&key.to_le_bytes()))] += 1;
        }
        for &c in &counts {
            // with 64 vnodes each of 3 replicas owns ≥ 10% of keys
            assert!(c >= 1000, "unbalanced ring: {counts:?}");
        }
        assert_eq!(counts.iter().sum::<usize>(), 10_000);
    }

    #[test]
    fn removing_a_replica_only_moves_its_keys() {
        let full = addrs(&["a:1", "b:1", "c:1"]);
        let ring = HashRing::new(&full, 64).unwrap();
        // drop "b:1"; survivors keep their indices in the reduced list
        let reduced = addrs(&["a:1", "c:1"]);
        let ring2 = HashRing::new(&reduced, 64).unwrap();
        let mut moved_foreign = 0;
        for key in 0..5_000u64 {
            let h = fnv1a(&key.to_le_bytes());
            let owner = &full[ring.route(h)];
            if owner != "b:1" {
                // a key NOT owned by the removed replica must keep its owner
                assert_eq!(owner, &reduced[ring2.route(h)], "key {key} reshuffled");
            } else {
                moved_foreign += 1;
            }
        }
        assert!(moved_foreign > 0, "test never exercised the removed replica");
    }

    #[test]
    fn order_walks_every_replica_from_the_owner() {
        let list = addrs(&["a:1", "b:1", "c:1", "d:1"]);
        let ring = HashRing::new(&list, 16).unwrap();
        let mut out = Vec::new();
        for key in 0..200u64 {
            let h = fnv1a(&key.to_le_bytes());
            ring.order(h, &mut out);
            assert_eq!(out.len(), 4);
            assert_eq!(out[0], ring.route(h));
            let mut sorted = out.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3]);
        }
    }
}
