//! Compressed data plane: factorize directly from sketched shards.
//!
//! The paper sketches the NNLS *subproblem* each iteration (Sec. 4); every
//! rank still holds its full raw block, so the deployable matrix size is
//! capped by per-rank RAM and disk. Following Chaudhry & Rebrova (arXiv
//! 2409.04994), this module stores only two **fixed** sketched views of
//! each rank's data and runs the multiplicative updates against them:
//!
//! * `u_view = M_{I_r:} · S_c`  (`|I_r| × d_c`) — the U-updates' data side,
//! * `v_view = (M_{:J_r})ᵀ · S_r` (`|J_r| × d_r`) — the V-updates' data side,
//!
//! with `S_c ∈ R^{cols×d_c}`, `S_r ∈ R^{rows×d_r}` drawn once from the
//! manifest seed (sub-Gaussian or CountSketch, reused from
//! [`crate::sketch`]). Disk, RAM residency, and bootstrap network all
//! shrink by roughly the compression ratio `R` (`d ≈ n/R`); the raw matrix
//! never exists on a worker.
//!
//! **Determinism.** The sketch pair is regenerated — never shipped — from
//! `(kind, dims, seed)` recorded in the manifest, at the reserved stream
//! cursor [`SKETCH_CURSOR`] of the same [`crate::rng::StreamRng`] that
//! drives the per-iteration subproblem sketches. Every rank, backend, and
//! re-joining replacement derives bit-identical sketches, so compressed
//! runs stay bit-identical across Sim/Tcp exactly like raw runs.
//!
//! **Trace semantics.** Without raw data the exact relative error is not
//! computable; runs on compressed input trace the compressed-domain proxy
//! `‖M·S_c − U·(VᵀS_c)ᵀ‖_F / ‖M·S_c‖_F` instead, against the exact
//! sketched norm recorded here at shard time (`sketched_fro_sq`).
//!
//! **On-disk format.** A compressed directory reuses the shard manifest
//! magic with format **version 3**: the v2 manifest body
//! ([`crate::data::shard::write_manifest_body`]) followed by the sketch
//! extension (kind, `d_r`, `d_c`, seed, sketched norm), plus one
//! `rank-{r}.cblk` view file per rank. The v2 reader rejects v3 with a
//! "this is a compressed shard set" diagnostic and vice versa; every parse
//! error names the offending file.

use std::io::{BufReader, BufWriter, Read, Write};
use std::ops::Range;
use std::path::{Path, PathBuf};

use crate::data::shard::{self, ShardManifest};
use crate::error::{Context, Result};
use crate::linalg::{Mat, Matrix};
use crate::rng::{Role, StreamRng};
use crate::sketch::{SketchKind, SketchMatrix};

/// On-disk format version of compressed shard sets. Version 3 extends the
/// v2 raw-shard manifest with the sketch extension; the two readers reject
/// each other's directories with typed diagnostics.
pub const COMPRESSED_FORMAT_VERSION: u32 = 3;

/// Reserved [`StreamRng`] iteration cursor for the *fixed* data sketches.
/// Per-iteration subproblem sketches use cursors `0..iterations`, so the
/// data sketches can never collide with them (and compressed runs replace
/// the per-iteration sketches anyway).
pub const SKETCH_CURSOR: u64 = u64::MAX;

const CBLOCK_MAGIC: &[u8; 8] = b"DSCPBLK1";

/// Error-message framing ("truncated compressed shard file …").
const IO: crate::binio::BinFormat = crate::binio::COMPRESSED;

/// Metadata of a compressed shard directory: the v2 base manifest (shape,
/// nodes, generator identity, exact **raw** `‖M‖²_F`, partitions) plus the
/// sketch extension every rank needs to regenerate `S_r`/`S_c` and to
/// normalise the compressed-domain error trace.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedManifest {
    /// The v2 manifest body (`fro_sq` is the exact *raw* norm, kept for
    /// provenance; compressed runs never consume it).
    pub base: ShardManifest,
    /// Sketch family of both fixed sketches.
    pub kind: SketchKind,
    /// Row-sketch width: `S_r ∈ R^{rows×d_r}` (V-updates' data side).
    pub d_r: usize,
    /// Column-sketch width: `S_c ∈ R^{cols×d_c}` (U-updates' data side).
    pub d_c: usize,
    /// Seed the fixed sketch pair is derived from (the manifest seed at
    /// shard time — recorded explicitly so the derivation is self-
    /// contained).
    pub sketch_seed: u64,
    /// Exact `‖M·S_c‖²_F`, accumulated in rank order at shard time — the
    /// denominator of the compressed-domain error trace and the factor-
    /// initialisation norm.
    pub sketched_fro_sq: f64,
}

/// One rank's compressed view: the two fixed sketched blocks plus the
/// regenerated sketch pair, resident for the whole run (zero per-iteration
/// sketch generation). This is what [`crate::data::NodeInput::Compressed`]
/// hands the runners.
#[derive(Debug, Clone)]
pub struct CompressedBlock {
    /// Global matrix rows.
    pub rows: usize,
    /// Global matrix columns.
    pub cols: usize,
    /// Global row indices `I_r` of `u_view`'s rows.
    pub row_range: Range<usize>,
    /// Global column indices `J_r` of `v_view`'s rows.
    pub col_range: Range<usize>,
    /// Sketch family.
    pub kind: SketchKind,
    /// Seed the sketch pair was derived from.
    pub sketch_seed: u64,
    /// Exact global `‖M·S_c‖²_F` (from the manifest).
    pub sketched_fro_sq: f64,
    u_view: Mat,
    v_view: Mat,
    s_c: SketchMatrix,
    s_r: SketchMatrix,
}

impl CompressedBlock {
    /// `M_{I_r:} · S_c` (`|I_r| × d_c`) — the U-updates' data operand.
    pub fn u_view(&self) -> &Mat {
        &self.u_view
    }

    /// `(M_{:J_r})ᵀ · S_r` (`|J_r| × d_r`) — the V-updates' data operand.
    pub fn v_view(&self) -> &Mat {
        &self.v_view
    }

    /// The fixed column sketch `S_c ∈ R^{cols×d_c}`.
    pub fn s_c(&self) -> &SketchMatrix {
        &self.s_c
    }

    /// The fixed row sketch `S_r ∈ R^{rows×d_r}`.
    pub fn s_r(&self) -> &SketchMatrix {
        &self.s_r
    }

    /// Column-sketch width `d_c` (the compressed run's effective `d_u`).
    pub fn d_c(&self) -> usize {
        self.s_c.d()
    }

    /// Row-sketch width `d_r` (the compressed run's effective `d_v`).
    pub fn d_r(&self) -> usize {
        self.s_r.d()
    }

    /// Resident bytes: both views plus the regenerated sketch pair (dense
    /// Gaussian sketches materialise `n×d` floats; the structured families
    /// are `O(n)`).
    pub fn resident_bytes(&self) -> usize {
        self.u_view.data().len() * 4
            + self.v_view.data().len() * 4
            + self.s_c.resident_bytes()
            + self.s_r.resident_bytes()
    }

    /// Load one rank's compressed view from a `dsanls shard --compress`
    /// directory, cross-checking the view file against the manifest and
    /// regenerating the sketch pair from the recorded derivation.
    pub fn load(dir: &Path, rank: usize) -> Result<(CompressedBlock, CompressedManifest)> {
        let man = read_compressed_manifest(dir)?;
        if rank >= man.base.nodes {
            crate::bail!("rank {rank} outside compressed shard set of {} nodes", man.base.nodes);
        }
        let path = cblock_path(dir, rank);
        let (row_range, col_range, u_view, v_view) = read_cblock_file(&path, rank, &man)
            .with_context(|| format!("reading compressed shard block {}", path.display()))?;
        let (s_r, s_c) =
            fixed_sketch_pair(man.kind, man.base.rows, man.base.cols, man.d_r, man.d_c, man.sketch_seed);
        Ok((
            CompressedBlock {
                rows: man.base.rows,
                cols: man.base.cols,
                row_range,
                col_range,
                kind: man.kind,
                sketch_seed: man.sketch_seed,
                sketched_fro_sq: man.sketched_fro_sq,
                u_view,
                v_view,
                s_c,
                s_r,
            },
            man,
        ))
    }
}

/// Derive the fixed sketch pair `(S_r, S_c)` from a seed. Deterministic in
/// `(kind, rows, cols, d_r, d_c, seed)`: every rank and every re-join
/// generates bit-identical sketches — they are recorded by derivation, not
/// shipped.
pub fn fixed_sketch_pair(
    kind: SketchKind,
    rows: usize,
    cols: usize,
    d_r: usize,
    d_c: usize,
    seed: u64,
) -> (SketchMatrix, SketchMatrix) {
    let stream = StreamRng::new(seed);
    let s_c =
        SketchMatrix::generate(kind, cols, d_c, &mut stream.for_iteration(SKETCH_CURSOR, Role::SketchU));
    let s_r =
        SketchMatrix::generate(kind, rows, d_r, &mut stream.for_iteration(SKETCH_CURSOR, Role::SketchV));
    (s_r, s_c)
}

/// Map a compression ratio `R` to sketch widths `d_r ≈ rows/R`,
/// `d_c ≈ cols/R`, clamped into the valid `1..=n` range.
pub fn ratio_dims(rows: usize, cols: usize, ratio: f64) -> Result<(usize, usize)> {
    if !(ratio >= 1.0 && ratio.is_finite()) {
        crate::bail!("compression ratio must be a finite value >= 1, got {ratio}");
    }
    let d_r = ((rows as f64 / ratio).round() as usize).clamp(1, rows);
    let d_c = ((cols as f64 / ratio).round() as usize).clamp(1, cols);
    Ok((d_r, d_c))
}

/// Path of one rank's compressed view file.
pub fn cblock_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("rank-{rank}.cblk"))
}

/// Sniff a shard directory's manifest format version (2 = raw, 3 =
/// compressed) without parsing the body — how `launch`/`worker` autodetect
/// which data plane a `--shards` directory belongs to.
pub fn manifest_version(dir: &Path) -> Result<u32> {
    let path = shard::manifest_path(dir);
    let sniff = |path: &Path| -> Result<u32> {
        let file = std::fs::File::open(path).context("opening file")?;
        let mut r = BufReader::new(file);
        let mut got = [0u8; 8];
        IO.read_exact(&mut r, &mut got, "magic")?;
        if &got != shard::MANIFEST_MAGIC {
            crate::bail!("bad magic {got:02x?} — not a dsanls shard manifest");
        }
        IO.read_u32(&mut r, "format version")
    };
    sniff(&path).with_context(|| format!("reading shard manifest {}", path.display()))
}

/// Write a complete compressed shard directory: the v3 manifest plus one
/// `rank-{r}.cblk` view file per rank, sketched from the materialised `m`
/// along the manifest's (uniform) partitions. Shard preparation is the one
/// place the full matrix may exist; workers then touch only their sketched
/// views. Returns the manifest (with the exact sketched norm filled in)
/// and the total bytes written.
pub fn write_compressed_dir(
    dir: &Path,
    m: &Matrix,
    base: &ShardManifest,
    kind: SketchKind,
    d_r: usize,
    d_c: usize,
) -> Result<(CompressedManifest, u64)> {
    assert_eq!((base.rows, base.cols), (m.rows(), m.cols()), "manifest/matrix shape");
    if base.is_balanced() {
        crate::bail!(
            "compressed shards assume uniform partitions — drop `--balance nnz` \
             (the sketched views have no per-column nnz to balance)"
        );
    }
    if !(1..=base.rows).contains(&d_r) || !(1..=base.cols).contains(&d_c) {
        crate::bail!(
            "sketch dims d_r={d_r}, d_c={d_c} outside 1..={} x 1..={} — pick a \
             smaller --ratio",
            base.rows,
            base.cols
        );
    }
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating compressed shard directory {}", dir.display()))?;
    let (s_r, s_c) = fixed_sketch_pair(kind, base.rows, base.cols, d_r, d_c, base.seed);
    let row_part = base.row_partition();
    let col_part = base.col_partition();
    let mut sketched_fro_sq = 0.0f64;
    let mut total = 0u64;
    for rank in 0..base.nodes {
        let rr = row_part.range(rank);
        let cr = col_part.range(rank);
        let u_view = s_c.mul_right(&m.row_block(rr.clone()));
        let v_view = s_r.mul_right(&m.col_block(cr.clone()).transpose());
        // rank-ordered accumulation: the same deterministic constant no
        // matter how the directory is later consumed
        sketched_fro_sq += u_view.fro_sq();
        total += write_cblock(dir, rank, base.nodes, &rr, &cr, &u_view, &v_view)?;
    }
    let man = CompressedManifest {
        base: base.clone(),
        kind,
        d_r,
        d_c,
        sketch_seed: base.seed,
        sketched_fro_sq,
    };
    total += write_compressed_manifest(dir, &man)?;
    Ok((man, total))
}

fn write_compressed_manifest(dir: &Path, man: &CompressedManifest) -> Result<u64> {
    let path = shard::manifest_path(dir);
    let file = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(shard::MANIFEST_MAGIC).context("writing compressed manifest magic")?;
    IO.write_u32(&mut w, COMPRESSED_FORMAT_VERSION)?;
    shard::write_manifest_body(&mut w, IO, &man.base)?;
    w.write_all(&[man.kind.code()]).context("writing sketch kind")?;
    IO.write_u64(&mut w, man.d_r as u64)?;
    IO.write_u64(&mut w, man.d_c as u64)?;
    IO.write_u64(&mut w, man.sketch_seed)?;
    IO.write_f64(&mut w, man.sketched_fro_sq)?;
    w.flush().context("flushing compressed manifest")?;
    Ok(std::fs::metadata(&path).map(|md| md.len()).unwrap_or(0))
}

/// Read and validate a compressed shard directory's manifest, with typed
/// rejection of raw (v1/v2) directories. Every parse error carries the
/// offending file path.
pub fn read_compressed_manifest(dir: &Path) -> Result<CompressedManifest> {
    let path = shard::manifest_path(dir);
    read_cmanifest_file(&path)
        .with_context(|| format!("reading compressed shard manifest {}", path.display()))
}

fn read_cmanifest_file(path: &Path) -> Result<CompressedManifest> {
    let file = std::fs::File::open(path).context("opening file")?;
    let mut r = BufReader::new(file);
    let mut got = [0u8; 8];
    IO.read_exact(&mut r, &mut got, "magic")?;
    if &got != shard::MANIFEST_MAGIC {
        crate::bail!("bad magic {got:02x?} — not a dsanls shard manifest");
    }
    let version = IO.read_u32(&mut r, "format version")?;
    if version != COMPRESSED_FORMAT_VERSION {
        crate::bail!(
            "format version {version} marks a *raw* shard set — this code path reads \
             compressed shards (version {COMPRESSED_FORMAT_VERSION}); re-shard with \
             `dsanls shard --compress` or point at a raw directory instead"
        );
    }
    let base = shard::read_manifest_body(&mut r, IO)?;
    let mut kind_b = [0u8; 1];
    IO.read_exact(&mut r, &mut kind_b, "sketch kind")?;
    let kind = SketchKind::from_code(kind_b[0])?;
    let d_r = IO.read_u64(&mut r, "row sketch dim")? as usize;
    let d_c = IO.read_u64(&mut r, "col sketch dim")? as usize;
    let sketch_seed = IO.read_u64(&mut r, "sketch seed")?;
    let sketched_fro_sq = IO.read_f64(&mut r, "sketched fro_sq")?;
    if !(1..=base.rows).contains(&d_r) || !(1..=base.cols).contains(&d_c) {
        crate::bail!(
            "sketch dims d_r={d_r}, d_c={d_c} outside the {}x{} matrix (corrupt file?)",
            base.rows,
            base.cols
        );
    }
    if !sketched_fro_sq.is_finite() || sketched_fro_sq < 0.0 {
        crate::bail!("sketched fro_sq {sketched_fro_sq} is not a norm (corrupt file?)");
    }
    Ok(CompressedManifest { base, kind, d_r, d_c, sketch_seed, sketched_fro_sq })
}

fn write_cblock(
    dir: &Path,
    rank: usize,
    nodes: usize,
    rr: &Range<usize>,
    cr: &Range<usize>,
    u_view: &Mat,
    v_view: &Mat,
) -> Result<u64> {
    let path = cblock_path(dir, rank);
    let file = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(CBLOCK_MAGIC).context("writing compressed block magic")?;
    IO.write_u32(&mut w, COMPRESSED_FORMAT_VERSION)?;
    IO.write_u64(&mut w, rank as u64)?;
    IO.write_u64(&mut w, nodes as u64)?;
    IO.write_u64(&mut w, rr.start as u64)?;
    IO.write_u64(&mut w, rr.end as u64)?;
    IO.write_u64(&mut w, cr.start as u64)?;
    IO.write_u64(&mut w, cr.end as u64)?;
    for view in [u_view, v_view] {
        IO.write_u64(&mut w, view.rows() as u64)?;
        IO.write_u64(&mut w, view.cols() as u64)?;
        IO.write_f32s(&mut w, view.data())?;
    }
    w.flush().context("flushing compressed block file")?;
    Ok(std::fs::metadata(&path).map(|md| md.len()).unwrap_or(0))
}

type CblockFields = (Range<usize>, Range<usize>, Mat, Mat);

fn read_cblock_file(path: &Path, rank: usize, man: &CompressedManifest) -> Result<CblockFields> {
    let file = std::fs::File::open(path).context("opening file")?;
    let mut r = BufReader::new(file);
    let mut got = [0u8; 8];
    IO.read_exact(&mut r, &mut got, "magic")?;
    if &got != CBLOCK_MAGIC {
        crate::bail!("bad magic {got:02x?} — not a dsanls compressed block file");
    }
    let version = IO.read_u32(&mut r, "format version")?;
    if version != COMPRESSED_FORMAT_VERSION {
        crate::bail!(
            "compressed block format version {version}, this binary reads \
             {COMPRESSED_FORMAT_VERSION} — regenerate with `dsanls shard --compress`"
        );
    }
    let file_rank = IO.read_u64(&mut r, "rank")? as usize;
    let nodes = IO.read_u64(&mut r, "nodes")? as usize;
    if file_rank != rank {
        crate::bail!("block file says rank {file_rank}, expected rank {rank}");
    }
    if nodes != man.base.nodes {
        crate::bail!(
            "block sharded for {nodes} nodes, manifest says {} (mixed shard sets?)",
            man.base.nodes
        );
    }
    let rs = IO.read_u64(&mut r, "row range start")? as usize;
    let re = IO.read_u64(&mut r, "row range end")? as usize;
    let cs = IO.read_u64(&mut r, "col range start")? as usize;
    let ce = IO.read_u64(&mut r, "col range end")? as usize;
    let rr = rs..re;
    let cr = cs..ce;
    if rr != man.base.row_partition().range(rank) || cr != man.base.col_partition().range(rank) {
        crate::bail!(
            "rank {rank} block spans rows {rr:?} cols {cr:?} but the manifest partitions \
             it at rows {:?} cols {:?} (mixed shard sets?)",
            man.base.row_partition().range(rank),
            man.base.col_partition().range(rank)
        );
    }
    let mut views = Vec::with_capacity(2);
    for (name, expect_rows, expect_cols) in
        [("u_view", rr.len(), man.d_c), ("v_view", cr.len(), man.d_r)]
    {
        let rows = IO.read_u64(&mut r, "view rows")? as usize;
        let cols = IO.read_u64(&mut r, "view cols")? as usize;
        if (rows, cols) != (expect_rows, expect_cols) {
            crate::bail!(
                "{name} is {rows}x{cols}, manifest implies {expect_rows}x{expect_cols} \
                 (corrupt file?)"
            );
        }
        // a corrupt length field must error, not attempt a huge allocation
        const MAX_ELEMS: usize = 1 << 31;
        let n = rows.saturating_mul(cols);
        if n > MAX_ELEMS {
            crate::bail!("{name} claims {n} values (corrupt length field?)");
        }
        let data = IO.read_f32s(&mut r, n, "view payload")?;
        views.push(Mat::from_vec(rows, cols, data));
    }
    let v_view = views.pop().expect("two views read");
    let u_view = views.pop().expect("two views read");
    Ok((rr, cr, u_view, v_view))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shard::matrix_bits_eq;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dsanls_compress_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn base_for(m: &Matrix, nodes: usize) -> ShardManifest {
        ShardManifest::uniform(
            nodes,
            m.rows(),
            m.cols(),
            m.fro_sq(),
            7,
            0.02,
            matches!(m, Matrix::Dense(_)),
            "FACE".into(),
        )
    }

    #[test]
    fn roundtrip_views_bit_identical_for_dense_and_sparse() {
        for d in [crate::data::Dataset::Face, crate::data::Dataset::Mnist] {
            let full = d.generate_scaled(7, 0.02);
            let base = base_for(&full, 2);
            let (d_r, d_c) = ratio_dims(full.rows(), full.cols(), 4.0).unwrap();
            let dir = tmpdir(&format!("rt_{d:?}"));
            let (man, bytes) = write_compressed_dir(
                &dir,
                &full,
                &base,
                SketchKind::CountSketch,
                d_r,
                d_c,
            )
            .unwrap();
            assert!(bytes > 0);
            assert_eq!(read_compressed_manifest(&dir).unwrap(), man);
            assert_eq!(manifest_version(&dir).unwrap(), COMPRESSED_FORMAT_VERSION);

            let (s_r, s_c) = fixed_sketch_pair(
                man.kind,
                full.rows(),
                full.cols(),
                d_r,
                d_c,
                man.sketch_seed,
            );
            for rank in 0..2 {
                let (blk, _) = CompressedBlock::load(&dir, rank).unwrap();
                let rr = base.row_partition().range(rank);
                let cr = base.col_partition().range(rank);
                assert_eq!((blk.row_range.clone(), blk.col_range.clone()), (rr.clone(), cr.clone()));
                let u_expect = s_c.mul_right(&full.row_block(rr));
                let v_expect = s_r.mul_right(&full.col_block(cr).transpose());
                assert!(
                    matrix_bits_eq(
                        &Matrix::Dense(u_expect),
                        &Matrix::Dense(blk.u_view().clone())
                    ),
                    "{d:?} rank {rank}: u_view mismatch"
                );
                assert!(
                    matrix_bits_eq(
                        &Matrix::Dense(v_expect),
                        &Matrix::Dense(blk.v_view().clone())
                    ),
                    "{d:?} rank {rank}: v_view mismatch"
                );
                assert!(blk.resident_bytes() > 0);
                assert_eq!(blk.sketched_fro_sq.to_bits(), man.sketched_fro_sq.to_bits());
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn sketch_regeneration_is_deterministic_across_loads() {
        let full = crate::data::Dataset::Face.generate_scaled(7, 0.02);
        let base = base_for(&full, 2);
        let (d_r, d_c) = ratio_dims(full.rows(), full.cols(), 3.0).unwrap();
        let dir = tmpdir("det");
        write_compressed_dir(&dir, &full, &base, SketchKind::Gaussian, d_r, d_c).unwrap();
        let (a, _) = CompressedBlock::load(&dir, 1).unwrap();
        let (b, _) = CompressedBlock::load(&dir, 1).unwrap();
        assert_eq!(a.u_view().data(), b.u_view().data());
        // the regenerated sketches apply bit-identically too
        let probe = Mat::from_vec(
            full.cols(),
            1,
            (0..full.cols()).map(|i| (i as f32).sin()).collect(),
        );
        let pa = a.s_c().mul_rows_tn(&probe, 0);
        let pb = b.s_c().mul_rows_tn(&probe, 0);
        assert_eq!(pa.data(), pb.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn raw_and_compressed_readers_reject_each_other() {
        let full = crate::data::Dataset::Face.generate_scaled(7, 0.02);
        let base = base_for(&full, 2);

        // raw dir: v3 reader refuses with a typed "raw shard set" message
        let raw = tmpdir("raw");
        shard::write_shard_dir(&raw, &full, &base).unwrap();
        assert_eq!(manifest_version(&raw).unwrap(), shard::SHARD_FORMAT_VERSION);
        let err = read_compressed_manifest(&raw).unwrap_err().to_string();
        assert!(err.contains("raw"), "{err}");
        assert!(err.contains("--compress"), "{err}");
        assert!(err.contains(shard::manifest_path(&raw).to_str().unwrap()), "{err}");

        // compressed dir: v2 reader refuses with a typed "compressed" message
        let cdir = tmpdir("cmp");
        let (d_r, d_c) = ratio_dims(full.rows(), full.cols(), 4.0).unwrap();
        write_compressed_dir(&cdir, &full, &base, SketchKind::CountSketch, d_r, d_c).unwrap();
        let err = shard::read_manifest(&cdir).unwrap_err().to_string();
        assert!(err.contains("compressed"), "{err}");
        assert!(err.contains(shard::manifest_path(&cdir).to_str().unwrap()), "{err}");

        std::fs::remove_dir_all(&raw).ok();
        std::fs::remove_dir_all(&cdir).ok();
    }

    #[test]
    fn truncated_and_corrupt_files_error_with_path() {
        let full = crate::data::Dataset::Face.generate_scaled(7, 0.02);
        let base = base_for(&full, 2);
        let dir = tmpdir("trunc");
        let (d_r, d_c) = ratio_dims(full.rows(), full.cols(), 4.0).unwrap();
        write_compressed_dir(&dir, &full, &base, SketchKind::CountSketch, d_r, d_c).unwrap();

        let mpath = shard::manifest_path(&dir);
        let bytes = std::fs::read(&mpath).unwrap();
        for cut in [0usize, 4, 8, 11, 20, bytes.len() - 1] {
            std::fs::write(&mpath, &bytes[..cut]).unwrap();
            let err = read_compressed_manifest(&dir).expect_err(&format!("cut at {cut}"));
            assert!(
                err.to_string().contains(mpath.to_str().unwrap()),
                "manifest error at cut {cut} lacks the file path: {err}"
            );
        }
        std::fs::write(&mpath, &bytes).unwrap();

        let bpath = cblock_path(&dir, 0);
        let bbytes = std::fs::read(&bpath).unwrap();
        for cut in [0usize, 7, 12, 30, bbytes.len() - 1] {
            std::fs::write(&bpath, &bbytes[..cut]).unwrap();
            let err = CompressedBlock::load(&dir, 0).expect_err(&format!("cut at {cut}"));
            assert!(
                err.to_string().contains(bpath.to_str().unwrap()),
                "block error at cut {cut} lacks the file path: {err}"
            );
        }

        // corrupt magic
        let mut mb = bbytes.clone();
        mb[0] ^= 0xFF;
        std::fs::write(&bpath, &mb).unwrap();
        assert!(CompressedBlock::load(&dir, 0).is_err());

        // missing rank file
        std::fs::write(&bpath, &bbytes).unwrap();
        assert!(CompressedBlock::load(&dir, 5).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ratio_dims_bounds_and_rejections() {
        assert_eq!(ratio_dims(100, 40, 4.0).unwrap(), (25, 10));
        assert_eq!(ratio_dims(3, 3, 100.0).unwrap(), (1, 1));
        assert_eq!(ratio_dims(10, 10, 1.0).unwrap(), (10, 10));
        assert!(ratio_dims(10, 10, 0.5).is_err());
        assert!(ratio_dims(10, 10, f64::NAN).is_err());
    }

    #[test]
    fn balanced_base_is_rejected() {
        let full = crate::data::Dataset::Face.generate_scaled(7, 0.02);
        let mut base = base_for(&full, 2);
        // skew the column cuts so is_balanced() fires
        let cols = full.cols();
        base.col_bounds = vec![0, cols - 1, cols];
        assert!(base.is_balanced());
        let dir = tmpdir("bal");
        let err =
            write_compressed_dir(&dir, &full, &base, SketchKind::CountSketch, 4, 4).unwrap_err();
        assert!(err.to_string().contains("uniform"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
