//! Datasets and partitioning.
//!
//! * [`partition`] — row/column index partitioning across nodes: uniform
//!   (Sec. 3.1 "near the same ... load balancing") and the skewed layout of
//!   Sec. 5.3.2 ("node 0 is assigned with 50 % of the columns").
//! * [`synth`] — synthetic matrix generators (low-rank+noise dense,
//!   power-law sparse) used as substitutes for the paper's real datasets.
//! * [`datasets`] — the six named Table-1 workloads, scaled (see DESIGN.md
//!   §2 for the substitution rationale).
//! * [`shard`] — the shard-aware data plane: rank-local block views
//!   ([`shard::NodeData`]), bit-identical shard-local synthesis, the
//!   on-disk `dsanls shard` format, and the exact distributed `‖M‖²`
//!   reduction.
//! * [`ingest`] — external matrix ingestion (COO text / MatrixMarket-style
//!   files) for `dsanls shard --input FILE`.
//! * [`compress`] — the compressed data plane: fixed sketched views of each
//!   rank's block (`dsanls shard --compress`), factorized directly without
//!   the raw matrix ever existing on a worker.

pub mod compress;
pub mod datasets;
pub mod ingest;
pub mod partition;
pub mod shard;
pub mod synth;

pub use compress::{CompressedBlock, CompressedManifest};
pub use datasets::{load, Dataset, DatasetSpec, ALL_DATASETS};
pub use partition::{imbalanced_partition, uniform_partition, Partition};
pub use shard::{Axis, LoadSource, LoadStats, NodeData, NodeInput, ShardManifest, ShardSpec};
