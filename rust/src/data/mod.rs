//! Datasets and partitioning.
//!
//! * [`partition`] — row/column index partitioning across nodes: uniform
//!   (Sec. 3.1 "near the same ... load balancing") and the skewed layout of
//!   Sec. 5.3.2 ("node 0 is assigned with 50 % of the columns").
//! * [`synth`] — synthetic matrix generators (low-rank+noise dense,
//!   power-law sparse) used as substitutes for the paper's real datasets.
//! * [`datasets`] — the six named Table-1 workloads, scaled (see DESIGN.md
//!   §2 for the substitution rationale).

pub mod datasets;
pub mod partition;
pub mod synth;

pub use datasets::{load, Dataset, DatasetSpec, ALL_DATASETS};
pub use partition::{imbalanced_partition, uniform_partition, Partition};
