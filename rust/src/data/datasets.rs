//! The six Table-1 workloads, scaled-down synthetic equivalents.
//!
//! | Paper dataset | Paper shape        | Ours (scaled)   | Generator |
//! |---------------|--------------------|-----------------|-----------|
//! | BOATS         | 216,000 × 300 dense| 10,800 × 300    | low-rank dense (video frames share background) |
//! | MIT CBCL FACE | 2,429 × 361 dense  | 2,429 × 361     | low-rank dense (kept full size — already small) |
//! | MNIST         | 70,000 × 784, 81 % sparse | 7,000 × 784 | blocky sparse strokes |
//! | GISETTE       | 13,500 × 5,000, 87 % sparse | 2,700 × 1,000 | blocky sparse |
//! | RCV1          | 804,414 × 47,236, 99.84 % sparse | 40,000 × 4,700 | power-law term-doc |
//! | DBLP          | 317,080², 99.998 % sparse | 20,000² | power-law graph |
//!
//! Scaling preserves aspect ratio, density class and planted rank; see
//! DESIGN.md §2 for why the convergence-curve *shapes* carry over.

use super::synth;
use crate::linalg::Matrix;
use crate::rng::{Pcg64, Role, StreamRng};

/// Named dataset identifiers (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    Boats,
    Face,
    Mnist,
    Gisette,
    Rcv1,
    Dblp,
}

/// All six, in the paper's order.
pub const ALL_DATASETS: [Dataset; 6] =
    [Dataset::Boats, Dataset::Face, Dataset::Mnist, Dataset::Gisette, Dataset::Rcv1, Dataset::Dblp];

/// Static description of a (scaled) dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub dense: bool,
    /// Paper's original shape, for the Table-1 bench printout.
    pub paper_rows: usize,
    pub paper_cols: usize,
    pub paper_sparsity: f64,
    /// Planted rank of the generator.
    pub true_rank: usize,
}

impl Dataset {
    pub fn spec(&self) -> DatasetSpec {
        match self {
            Dataset::Boats => DatasetSpec {
                name: "BOATS",
                rows: 10_800,
                cols: 300,
                dense: true,
                paper_rows: 216_000,
                paper_cols: 300,
                paper_sparsity: 0.0,
                true_rank: 12,
            },
            Dataset::Face => DatasetSpec {
                name: "FACE",
                rows: 2_429,
                cols: 361,
                dense: true,
                paper_rows: 2_429,
                paper_cols: 361,
                paper_sparsity: 0.0,
                true_rank: 16,
            },
            Dataset::Mnist => DatasetSpec {
                name: "MNIST",
                rows: 7_000,
                cols: 784,
                dense: false,
                paper_rows: 70_000,
                paper_cols: 784,
                paper_sparsity: 0.8086,
                true_rank: 10,
            },
            Dataset::Gisette => DatasetSpec {
                name: "GISETTE",
                rows: 2_700,
                cols: 1_000,
                dense: false,
                paper_rows: 13_500,
                paper_cols: 5_000,
                paper_sparsity: 0.8701,
                true_rank: 10,
            },
            Dataset::Rcv1 => DatasetSpec {
                name: "RCV1",
                rows: 40_000,
                cols: 4_700,
                dense: false,
                paper_rows: 804_414,
                paper_cols: 47_236,
                paper_sparsity: 0.9984,
                true_rank: 40,
            },
            Dataset::Dblp => DatasetSpec {
                name: "DBLP",
                rows: 20_000,
                cols: 20_000,
                dense: false,
                paper_rows: 317_080,
                paper_cols: 317_080,
                paper_sparsity: 0.999976,
                true_rank: 30,
            },
        }
    }

    pub fn from_name(s: &str) -> Option<Dataset> {
        match s.to_ascii_uppercase().as_str() {
            "BOATS" => Some(Dataset::Boats),
            "FACE" => Some(Dataset::Face),
            "MNIST" => Some(Dataset::Mnist),
            "GISETTE" => Some(Dataset::Gisette),
            "RCV1" => Some(Dataset::Rcv1),
            "DBLP" => Some(Dataset::Dblp),
            _ => None,
        }
    }

    /// Generate the matrix at full scaled size.
    pub fn generate(&self, seed: u64) -> Matrix {
        self.generate_scaled(seed, 1.0)
    }

    /// Raw scaled row/column counts (before the DBLP squaring) — internal
    /// inputs to the generators; [`Dataset::scaled_shape`] gives the shape
    /// of the produced matrix.
    fn scaled_dims(&self, scale: f64) -> (usize, usize) {
        let spec = self.spec();
        let rows = ((spec.rows as f64 * scale) as usize).max(64);
        let cols = ((spec.cols as f64 * scale.sqrt()) as usize).max(64).min(spec.cols);
        (rows, cols)
    }

    /// Shape of the matrix `generate_scaled(seed, scale)` produces, without
    /// generating it — what the shard planner partitions over.
    pub fn scaled_shape(&self, scale: f64) -> (usize, usize) {
        let (rows, cols) = self.scaled_dims(scale);
        match self {
            // the graph is square over max(rows, cols) nodes
            Dataset::Dblp => {
                let n = rows.max(cols);
                (n, n)
            }
            _ => (rows, cols),
        }
    }

    /// Generate at `scale` ∈ (0, 1] of the scaled size (tests use 0.05-ish;
    /// row/col counts floor at 64).
    pub fn generate_scaled(&self, seed: u64, scale: f64) -> Matrix {
        let (rows, cols) = self.scaled_shape(scale);
        self.generate_window(seed, scale, 0..rows, 0..cols)
    }

    /// Shard-local generation: materialise only the `rows × cols` window of
    /// the scaled matrix, **bit-identical** to slicing the full
    /// `generate_scaled(seed, scale)` output (the windowed generators
    /// replay the full random stream — see [`synth`]). Peak memory is the
    /// window plus factor-sized scratch.
    pub fn generate_window(
        &self,
        seed: u64,
        scale: f64,
        rows: std::ops::Range<usize>,
        cols: std::ops::Range<usize>,
    ) -> Matrix {
        let w = synth::GenWindow { rows, cols };
        self.generate_windows(seed, scale, std::slice::from_ref(&w))
            .pop()
            .expect("one window in, one block out")
    }

    /// Multi-window shard-local generation: fill **every** window in a
    /// single replay of the generator stream (a DSANLS rank holds both its
    /// row and its column block — one pass instead of one replay per block
    /// halves shard-local generation CPU). Each returned block is
    /// bit-identical to a dedicated [`Dataset::generate_window`] call.
    pub fn generate_windows(
        &self,
        seed: u64,
        scale: f64,
        windows: &[synth::GenWindow],
    ) -> Vec<Matrix> {
        let spec = self.spec();
        let (g_rows, g_cols) = self.scaled_dims(scale);
        let mut rng: Pcg64 = StreamRng::new(seed).for_iteration(*self as u64, Role::Data);
        match self {
            Dataset::Boats | Dataset::Face => synth::low_rank_dense_windows(
                g_rows,
                g_cols,
                spec.true_rank,
                if matches!(self, Dataset::Boats) { 0.05 } else { 0.08 },
                windows,
                &mut rng,
            )
            .into_iter()
            .map(Matrix::Dense)
            .collect(),
            Dataset::Mnist | Dataset::Gisette => synth::blocky_sparse_windows(
                g_rows,
                g_cols,
                spec.true_rank,
                1.0 - spec.paper_sparsity,
                windows,
                &mut rng,
            )
            .into_iter()
            .map(Matrix::Sparse)
            .collect(),
            Dataset::Rcv1 => {
                let nnz = ((g_rows * g_cols) as f64 * (1.0 - spec.paper_sparsity) * 4.0) as usize;
                synth::power_law_sparse_windows(
                    g_rows,
                    g_cols,
                    nnz.max(10 * g_rows),
                    spec.true_rank,
                    1.05,
                    windows,
                    &mut rng,
                )
                .into_iter()
                .map(Matrix::Sparse)
                .collect()
            }
            Dataset::Dblp => {
                let edges = (g_rows as f64 * 7.6) as usize; // matches paper's avg degree
                synth::power_law_graph_windows(g_rows.max(g_cols), edges, windows, &mut rng)
                    .into_iter()
                    .map(Matrix::Sparse)
                    .collect()
            }
        }
    }
}

/// Generate a named dataset (scaled) by name string.
pub fn load(name: &str, seed: u64, scale: f64) -> Option<Matrix> {
    Dataset::from_name(name).map(|d| d.generate_scaled(seed, scale))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_specs_consistent() {
        for d in ALL_DATASETS {
            let s = d.spec();
            assert!(s.rows > 0 && s.cols > 0);
            assert!(s.true_rank < s.cols);
            assert_eq!(Dataset::from_name(s.name), Some(d));
        }
    }

    #[test]
    fn tiny_generation_matches_kind() {
        for d in ALL_DATASETS {
            let m = d.generate_scaled(7, 0.02);
            let s = d.spec();
            match (&m, s.dense) {
                (Matrix::Dense(_), true) | (Matrix::Sparse(_), false) => {}
                _ => panic!("{}: wrong storage kind", s.name),
            }
            assert!(m.rows() >= 64);
            assert!(m.fro_sq() > 0.0);
        }
    }

    #[test]
    fn sparse_datasets_are_sparse() {
        for d in [Dataset::Rcv1, Dataset::Dblp] {
            if let Matrix::Sparse(s) = d.generate_scaled(7, 0.02) {
                assert!(s.density() < 0.2, "{:?} density {}", d, s.density());
            } else {
                panic!("expected sparse");
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Dataset::Mnist.generate_scaled(5, 0.02);
        let b = Dataset::Mnist.generate_scaled(5, 0.02);
        assert_eq!(a.fro_sq(), b.fro_sq());
        assert_eq!(a.nnz(), b.nnz());
    }

    #[test]
    fn scaled_shape_matches_generated() {
        for d in ALL_DATASETS {
            let (rows, cols) = d.scaled_shape(0.02);
            let m = d.generate_scaled(7, 0.02);
            assert_eq!((m.rows(), m.cols()), (rows, cols), "{:?}", d);
        }
    }

    #[test]
    fn dual_window_single_pass_matches_two_pass() {
        // what a DSANLS rank does: row block + column block from ONE
        // generator replay, bit-identical to two dedicated replays
        for d in ALL_DATASETS {
            let (rows, cols) = d.scaled_shape(0.02);
            let rr = rows / 4..rows / 2;
            let cc = cols / 3..cols / 2 + 1;
            let ws = [
                synth::GenWindow { rows: rr.clone(), cols: 0..cols },
                synth::GenWindow { rows: 0..rows, cols: cc.clone() },
            ];
            let both = d.generate_windows(13, 0.02, &ws);
            let row_blk = d.generate_window(13, 0.02, rr, 0..cols);
            let col_blk = d.generate_window(13, 0.02, 0..rows, cc);
            assert!(
                crate::data::shard::matrix_bits_eq(&both[0], &row_blk),
                "{d:?}: one-pass row block != two-pass"
            );
            assert!(
                crate::data::shard::matrix_bits_eq(&both[1], &col_blk),
                "{d:?}: one-pass col block != two-pass"
            );
        }
    }

    #[test]
    fn window_generation_is_a_bitwise_slice() {
        for d in ALL_DATASETS {
            let (rows, cols) = d.scaled_shape(0.02);
            let full = d.generate_scaled(11, 0.02);
            let (r, c) = (rows / 3..rows / 3 + rows / 4, cols / 5..cols / 5 + cols / 3);
            let block = d.generate_window(11, 0.02, r.clone(), c.clone());
            let slice = full.row_block(r).col_block(c);
            assert!(
                crate::data::shard::matrix_bits_eq(&slice, &block),
                "{:?}: window != slice",
                d
            );
        }
    }
}
