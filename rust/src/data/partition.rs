//! Index partitioning across cluster nodes.

/// A partition of `0..total` into `n` contiguous, disjoint ranges
/// (one per node, rank-ordered).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub total: usize,
    ranges: Vec<std::ops::Range<usize>>,
}

impl Partition {
    /// Rebuild a partition from its `n + 1` cut points
    /// (`[0, b₁, …, total]`) — the form shard manifests persist.
    /// Malformed bounds (non-monotone, not starting at 0) are a typed
    /// error: they come from files.
    pub fn from_bounds(bounds: &[usize]) -> crate::error::Result<Partition> {
        if bounds.len() < 2 || bounds[0] != 0 {
            crate::bail!("partition bounds must start at 0 and list n+1 cut points");
        }
        let mut ranges = Vec::with_capacity(bounds.len() - 1);
        for w in bounds.windows(2) {
            if w[1] < w[0] {
                crate::bail!("partition bounds are not monotone: {} then {}", w[0], w[1]);
            }
            ranges.push(w[0]..w[1]);
        }
        Ok(Partition { total: *bounds.last().unwrap(), ranges })
    }

    /// The `n + 1` cut points (`[0, b₁, …, total]`) of this partition.
    pub fn bounds(&self) -> Vec<usize> {
        let mut b = Vec::with_capacity(self.ranges.len() + 1);
        b.push(0);
        b.extend(self.ranges.iter().map(|r| r.end));
        b
    }
    pub fn nodes(&self) -> usize {
        self.ranges.len()
    }

    /// The index range owned by `rank`.
    pub fn range(&self, rank: usize) -> std::ops::Range<usize> {
        self.ranges[rank].clone()
    }

    /// Size of the block owned by `rank`.
    pub fn len(&self, rank: usize) -> usize {
        self.ranges[rank].len()
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Offset of `rank`'s block in the global ordering.
    pub fn offset(&self, rank: usize) -> usize {
        self.ranges[rank].start
    }

    /// Which rank owns global index `i`.
    pub fn owner(&self, i: usize) -> usize {
        debug_assert!(i < self.total);
        self.ranges.iter().position(|r| r.contains(&i)).expect("index outside partition")
    }

    /// Sanity: ranges are contiguous, disjoint and cover `0..total`.
    pub fn validate(&self) -> bool {
        let mut prev = 0;
        for r in &self.ranges {
            if r.start != prev {
                return false;
            }
            prev = r.end;
        }
        prev == self.total
    }
}

/// Uniform partition: `|I_r| ≈ total/N` (paper Sec. 3.1).
pub fn uniform_partition(total: usize, nodes: usize) -> Partition {
    Partition { total, ranges: crate::parallel::split_ranges(total, nodes) }
}

/// Imbalanced partition of Sec. 5.3.2: node 0 holds `skew` (e.g. 0.5 = 50 %)
/// of the indices; the remainder is spread uniformly over nodes 1..N.
pub fn imbalanced_partition(total: usize, nodes: usize, skew: f64) -> Partition {
    assert!(nodes >= 1);
    assert!((0.0..1.0).contains(&skew));
    if nodes == 1 {
        return uniform_partition(total, 1);
    }
    let first = ((total as f64) * skew).round() as usize;
    let first = first.min(total);
    let rest = crate::parallel::split_ranges(total - first, nodes - 1);
    let mut ranges = Vec::with_capacity(nodes);
    ranges.push(0..first);
    for r in rest {
        ranges.push(first + r.start..first + r.end);
    }
    Partition { total, ranges }
}

/// Weight-balanced partition: cut `0..weights.len()` into `nodes`
/// contiguous ranges so each holds ≈ `Σweights / nodes` of the total
/// weight (greedy cumulative cuts). With per-column nnz counts as the
/// weights this is `dsanls shard --balance nnz`: on a skewed matrix every
/// secure party ends up holding a comparable number of stored values, so
/// the synchronous protocols stop stalling on the heavy party (the
/// ROADMAP's "skew-aware shard files" item). Ranks are never starved: a
/// cut leaves at least one index per remaining rank while indices last.
pub fn weight_balanced_partition(weights: &[usize], nodes: usize) -> Partition {
    assert!(nodes >= 1);
    let n = weights.len();
    let total: u128 = weights.iter().map(|&w| w as u128).sum();
    let mut ranges = Vec::with_capacity(nodes);
    let mut cum: u128 = 0;
    let mut idx = 0usize;
    for r in 0..nodes {
        let start = idx;
        if r + 1 == nodes {
            idx = n;
        } else {
            let target = total * (r as u128 + 1) / nodes as u128;
            let reserve = nodes - 1 - r; // leave ≥1 index per remaining rank
            while idx < n.saturating_sub(reserve) && (cum < target || idx == start) {
                cum += weights[idx] as u128;
                idx += 1;
            }
        }
        ranges.push(start..idx);
    }
    Partition { total: n, ranges }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_covers_and_balances() {
        for total in [10usize, 100, 101, 7] {
            for n in [1usize, 2, 3, 7] {
                let p = uniform_partition(total, n);
                assert!(p.validate(), "{total}/{n}");
                let max = (0..n).map(|r| p.len(r)).max().unwrap();
                let min = (0..n).map(|r| p.len(r)).min().unwrap();
                assert!(max - min <= 1, "imbalanced uniform partition");
            }
        }
    }

    #[test]
    fn imbalanced_gives_node0_the_skew() {
        let p = imbalanced_partition(1000, 10, 0.5);
        assert!(p.validate());
        assert_eq!(p.len(0), 500);
        for r in 1..10 {
            assert!((p.len(r) as i64 - 56).abs() <= 1, "len({r}) = {}", p.len(r));
        }
    }

    #[test]
    fn bounds_roundtrip() {
        for p in [uniform_partition(101, 4), imbalanced_partition(60, 3, 0.5)] {
            let back = Partition::from_bounds(&p.bounds()).unwrap();
            assert_eq!(back, p);
            assert!(back.validate());
        }
        assert!(Partition::from_bounds(&[]).is_err());
        assert!(Partition::from_bounds(&[1, 5]).is_err(), "must start at 0");
        assert!(Partition::from_bounds(&[0, 7, 3]).is_err(), "must be monotone");
    }

    #[test]
    fn weight_balanced_splits_skewed_weights() {
        // one heavy prefix: uniform-by-count would give rank 0 ~all weight
        let mut w = vec![100usize; 10];
        w.extend(std::iter::repeat(1).take(90));
        let p = weight_balanced_partition(&w, 4);
        assert!(p.validate());
        let weight_of = |r: usize| p.range(r).map(|i| w[i]).sum::<usize>();
        let total: usize = w.iter().sum();
        for r in 0..4 {
            let share = weight_of(r) as f64 / total as f64;
            assert!(
                (0.10..=0.45).contains(&share),
                "rank {r} holds {share:.2} of the weight: {:?}",
                (0..4).map(weight_of).collect::<Vec<_>>()
            );
        }
        // uniform weights degrade to ≈uniform cuts
        let p = weight_balanced_partition(&[1; 100], 4);
        for r in 0..4 {
            assert_eq!(p.len(r), 25);
        }
        // more ranks than indices: every index still covered, in order
        let p = weight_balanced_partition(&[5, 5], 4);
        assert!(p.validate());
        assert_eq!(p.total, 2);
    }

    #[test]
    fn owner_lookup() {
        let p = uniform_partition(100, 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(99), 3);
        assert_eq!(p.owner(p.offset(2)), 2);
    }
}
