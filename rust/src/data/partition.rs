//! Index partitioning across cluster nodes.

/// A partition of `0..total` into `n` contiguous, disjoint ranges
/// (one per node, rank-ordered).
#[derive(Debug, Clone)]
pub struct Partition {
    pub total: usize,
    ranges: Vec<std::ops::Range<usize>>,
}

impl Partition {
    pub fn nodes(&self) -> usize {
        self.ranges.len()
    }

    /// The index range owned by `rank`.
    pub fn range(&self, rank: usize) -> std::ops::Range<usize> {
        self.ranges[rank].clone()
    }

    /// Size of the block owned by `rank`.
    pub fn len(&self, rank: usize) -> usize {
        self.ranges[rank].len()
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Offset of `rank`'s block in the global ordering.
    pub fn offset(&self, rank: usize) -> usize {
        self.ranges[rank].start
    }

    /// Which rank owns global index `i`.
    pub fn owner(&self, i: usize) -> usize {
        debug_assert!(i < self.total);
        self.ranges.iter().position(|r| r.contains(&i)).expect("index outside partition")
    }

    /// Sanity: ranges are contiguous, disjoint and cover `0..total`.
    pub fn validate(&self) -> bool {
        let mut prev = 0;
        for r in &self.ranges {
            if r.start != prev {
                return false;
            }
            prev = r.end;
        }
        prev == self.total
    }
}

/// Uniform partition: `|I_r| ≈ total/N` (paper Sec. 3.1).
pub fn uniform_partition(total: usize, nodes: usize) -> Partition {
    Partition { total, ranges: crate::parallel::split_ranges(total, nodes) }
}

/// Imbalanced partition of Sec. 5.3.2: node 0 holds `skew` (e.g. 0.5 = 50 %)
/// of the indices; the remainder is spread uniformly over nodes 1..N.
pub fn imbalanced_partition(total: usize, nodes: usize, skew: f64) -> Partition {
    assert!(nodes >= 1);
    assert!((0.0..1.0).contains(&skew));
    if nodes == 1 {
        return uniform_partition(total, 1);
    }
    let first = ((total as f64) * skew).round() as usize;
    let first = first.min(total);
    let rest = crate::parallel::split_ranges(total - first, nodes - 1);
    let mut ranges = Vec::with_capacity(nodes);
    ranges.push(0..first);
    for r in rest {
        ranges.push(first + r.start..first + r.end);
    }
    Partition { total, ranges }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_covers_and_balances() {
        for total in [10usize, 100, 101, 7] {
            for n in [1usize, 2, 3, 7] {
                let p = uniform_partition(total, n);
                assert!(p.validate(), "{total}/{n}");
                let max = (0..n).map(|r| p.len(r)).max().unwrap();
                let min = (0..n).map(|r| p.len(r)).min().unwrap();
                assert!(max - min <= 1, "imbalanced uniform partition");
            }
        }
    }

    #[test]
    fn imbalanced_gives_node0_the_skew() {
        let p = imbalanced_partition(1000, 10, 0.5);
        assert!(p.validate());
        assert_eq!(p.len(0), 500);
        for r in 1..10 {
            assert!((p.len(r) as i64 - 56).abs() <= 1, "len({r}) = {}", p.len(r));
        }
    }

    #[test]
    fn owner_lookup() {
        let p = uniform_partition(100, 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(99), 3);
        assert_eq!(p.owner(p.offset(2)), 2);
    }
}
