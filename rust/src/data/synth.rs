//! Synthetic matrix generators — the data substitutes (DESIGN.md §2).
//!
//! Each generator matches the *structure* that makes the paper's datasets
//! behave as they do under NMF: approximate nonnegative low-rank for the
//! dense image/video matrices, heavy-tailed sparse co-occurrence for the
//! text/graph matrices.

use crate::linalg::{Csr, Mat, Matrix};
use crate::rng::{Gaussian, Pcg64};

/// Dense nonnegative low-rank + noise:
/// `M = U₀·V₀ᵀ + σ·|noise|`, entries clipped at 0.
///
/// `true_rank` controls the planted structure (≈ phenotypes / video
/// background components); `noise` the residual floor an NMF of rank
/// ≥ true_rank can reach.
pub fn low_rank_dense(
    rows: usize,
    cols: usize,
    true_rank: usize,
    noise: f32,
    rng: &mut Pcg64,
) -> Mat {
    let u = Mat::rand_uniform(rows, true_rank, 1.0, rng);
    let v = Mat::rand_uniform(cols, true_rank, 1.0, rng);
    let mut m = u.matmul_nt(&v);
    if noise > 0.0 {
        let mut g = Gaussian::new(rng.clone());
        for x in m.data_mut().iter_mut() {
            *x += g.sample_f32(noise).abs();
        }
        // keep caller's rng moving
        for _ in 0..rows * cols {
            rng.next_u64();
        }
    }
    m
}

/// Sparse power-law matrix (bag-of-words / term-document): column
/// popularity follows Zipf with exponent `zipf`, row activity is uniform;
/// values are 1 + Exp-like counts. Also plants `true_rank` soft topics so
/// NMF has structure to find.
pub fn power_law_sparse(
    rows: usize,
    cols: usize,
    nnz_target: usize,
    true_rank: usize,
    zipf: f64,
    rng: &mut Pcg64,
) -> Csr {
    // topic model: each row gets a topic, each topic a column distribution
    // biased by Zipf rank; draws cluster within topics.
    let mut weights: Vec<f64> = (0..cols).map(|c| 1.0 / ((c + 1) as f64).powf(zipf)).collect();
    let wsum: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= wsum;
    }
    // cumulative for inverse-CDF sampling
    let mut cdf = Vec::with_capacity(cols);
    let mut acc = 0.0;
    for &w in &weights {
        acc += w;
        cdf.push(acc);
    }
    let sample_col = |r: &mut Pcg64| -> usize {
        let x = r.next_f64();
        match cdf.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cols - 1),
        }
    };

    let k = true_rank.max(1);
    let row_topic: Vec<usize> = (0..rows).map(|_| rng.below(k)).collect();
    let mut triplets = Vec::with_capacity(nnz_target);
    for _ in 0..nnz_target {
        let i = rng.below(rows);
        // topic shift: rotate the sampled column by a topic-dependent offset
        // so different topics emphasise different column bands
        let base = sample_col(rng);
        let j = (base + row_topic[i] * (cols / k.max(1))) % cols;
        let v = 1.0 + (rng.next_f32() * 4.0).floor(); // count-like 1..=4
        triplets.push((i, j, v));
    }
    Csr::from_triplets(rows, cols, triplets)
}

/// Symmetric power-law graph adjacency (DBLP-like co-authorship):
/// preferential-attachment-flavoured edge endpoints, symmetrised.
pub fn power_law_graph(nodes: usize, edges: usize, rng: &mut Pcg64) -> Csr {
    let mut triplets = Vec::with_capacity(edges * 2);
    for _ in 0..edges {
        // endpoint ∝ (rank+1)^-0.8 via rejection-free inverse power draw
        let a = power_index(nodes, 0.8, rng);
        let b = power_index(nodes, 0.8, rng);
        if a == b {
            continue;
        }
        triplets.push((a, b, 1.0));
        triplets.push((b, a, 1.0));
    }
    Csr::from_triplets(nodes, nodes, triplets)
}

fn power_index(n: usize, alpha: f64, rng: &mut Pcg64) -> usize {
    // inverse-CDF of p(i) ∝ (i+1)^(−alpha) approximated by u^(1/(1−alpha))
    let u = rng.next_f64().max(1e-12);
    let x = u.powf(1.0 / (1.0 - alpha));
    ((x * n as f64) as usize).min(n - 1)
}

/// MNIST-like: blocky nonnegative "digit strokes" with ~20 % density.
/// Rows = images (mixtures of `true_rank` stroke templates), cols = pixels.
pub fn blocky_sparse(
    rows: usize,
    cols: usize,
    true_rank: usize,
    density: f64,
    rng: &mut Pcg64,
) -> Csr {
    // templates: each covers a contiguous band of pixels
    let k = true_rank.max(1);
    let band = (cols as f64 * density * 2.0).ceil() as usize;
    let band = band.clamp(1, cols);
    let mut triplets = Vec::new();
    for i in 0..rows {
        // each image mixes 1–3 templates
        let n_tpl = 1 + rng.below(3);
        for _ in 0..n_tpl {
            let t = rng.below(k);
            let start = (t * cols / k) % cols;
            // within the band, keep ~half the pixels
            for j in 0..band {
                if rng.next_f32() < 0.5 {
                    let col = (start + j) % cols;
                    let v = 0.2 + rng.next_f32();
                    triplets.push((i, col, v));
                }
            }
        }
    }
    Csr::from_triplets(rows, cols, triplets)
}

/// Wrap a generator output in [`Matrix`], choosing dense/sparse storage by
/// the achieved density.
pub fn auto_storage(m: Csr) -> Matrix {
    if m.density() > 0.5 {
        Matrix::Dense(m.to_dense())
    } else {
        Matrix::Sparse(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_rank_is_nonnegative_and_low_rank() {
        let mut rng = Pcg64::new(100, 0);
        let m = low_rank_dense(30, 20, 3, 0.01, &mut rng);
        assert!(m.is_nonnegative());
        // rank-3 NMF should reach small error
        let f = crate::nmf::Anls::new(crate::nmf::AnlsOptions {
            rank: 3,
            iterations: 60,
            solver: crate::solvers::SolverKind::Hals,
            inner_sweeps: 2,
            ..Default::default()
        })
        .run(&Matrix::Dense(m));
        assert!(f.final_error() < 0.12, "err = {}", f.final_error());
    }

    #[test]
    fn power_law_sparse_hits_density() {
        let mut rng = Pcg64::new(101, 0);
        let m = power_law_sparse(500, 300, 6000, 5, 1.1, &mut rng);
        assert_eq!(m.rows(), 500);
        assert!(m.nnz() > 4000, "nnz = {}", m.nnz());
        assert!(m.density() < 0.05);
        assert!(m.values().iter().all(|&v| v >= 1.0));
    }

    #[test]
    fn graph_is_symmetric() {
        let mut rng = Pcg64::new(102, 0);
        let g = power_law_graph(100, 400, &mut rng);
        let d = g.to_dense();
        for i in 0..100 {
            for j in 0..100 {
                assert_eq!(d.get(i, j), d.get(j, i));
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let gen = || {
            let mut rng = Pcg64::new(103, 0);
            power_law_sparse(100, 80, 800, 4, 1.0, &mut rng)
        };
        assert_eq!(gen().values(), gen().values());
    }

    #[test]
    fn blocky_has_reasonable_density() {
        let mut rng = Pcg64::new(104, 0);
        let m = blocky_sparse(200, 196, 8, 0.2, &mut rng);
        let d = m.density();
        assert!(d > 0.02 && d < 0.6, "density {d}");
    }
}
