//! Synthetic matrix generators — the data substitutes (DESIGN.md §2).
//!
//! Each generator matches the *structure* that makes the paper's datasets
//! behave as they do under NMF: approximate nonnegative low-rank for the
//! dense image/video matrices, heavy-tailed sparse co-occurrence for the
//! text/graph matrices.
//!
//! ## Windowed (shard-local) generation
//!
//! Every generator has a `*_window` variant that materialises only the
//! entries inside a [`GenWindow`] (a row range × column range) while
//! **replaying the exact random stream of the full-matrix generation**.
//! This is the shard data plane's core trick: rank `r` of a cluster calls
//! the windowed generator for its block and obtains buffers that are
//! **bit-identical** to slicing the full matrix — without ever holding the
//! full matrix (peak memory is the block, CPU replays the full draw
//! stream, which is cheap relative to the factorization itself). The
//! unwindowed entry points are thin wrappers over the full window, so
//! there is exactly one generation code path to keep in sync.
//!
//! Each generator's `*_windows` form fills **several** windows in a single
//! replay — a DSANLS rank needs both its row block and its column block,
//! and replaying the stream once per block would cost 2× full-generation
//! CPU per rank ([`crate::data::shard::NodeData::generate`] uses the
//! single-pass form). Per-window outputs are bit-identical to dedicated
//! single-window replays (asserted by
//! `multi_window_single_pass_matches_two_pass`).

use std::ops::Range;

use crate::linalg::{Csr, Mat, Matrix};
use crate::rng::{Gaussian, Pcg64};

/// A row-range × column-range window of a (virtual) full matrix, selecting
/// which entries a windowed generator materialises.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenWindow {
    /// Global row indices to keep.
    pub rows: Range<usize>,
    /// Global column indices to keep.
    pub cols: Range<usize>,
}

impl GenWindow {
    /// The whole matrix (windowed generation degenerates to full).
    pub fn full(rows: usize, cols: usize) -> GenWindow {
        GenWindow { rows: 0..rows, cols: 0..cols }
    }

    /// Window height × width.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows.len(), self.cols.len())
    }

    fn contains(&self, i: usize, j: usize) -> bool {
        self.rows.contains(&i) && self.cols.contains(&j)
    }

    fn validate(&self, rows: usize, cols: usize) {
        assert!(self.rows.end <= rows, "window rows {:?} exceed {rows}", self.rows);
        assert!(self.cols.end <= cols, "window cols {:?} exceed {cols}", self.cols);
    }

    /// Expected share of `total` uniformly-spread draws landing in the
    /// window of a `rows × cols` matrix (triplet-vector capacity hint;
    /// the full window returns `total` exactly).
    fn expected_hits(&self, rows: usize, cols: usize, total: usize) -> usize {
        let cells = (rows * cols).max(1);
        let frac = (self.rows.len() * self.cols.len()) as f64 / cells as f64;
        (total as f64 * frac).ceil() as usize
    }
}

/// Draw a `total×k` Uniform[0, scale) matrix with the exact draw order of
/// [`Mat::rand_uniform`], storing each kept row into **every** window whose
/// range contains it — one pass over the stream no matter how many windows
/// are filled. Each returned matrix is bit-identical to what a dedicated
/// single-window replay would produce (every row's values are drawn exactly
/// once, in global order, kept or not).
fn rand_uniform_row_windows(
    total: usize,
    k: usize,
    scale: f32,
    keeps: &[Range<usize>],
    rng: &mut Pcg64,
) -> Vec<Mat> {
    let mut outs: Vec<Mat> = keeps.iter().map(|keep| Mat::zeros(keep.len(), k)).collect();
    for i in 0..total {
        if keeps.iter().any(|keep| keep.contains(&i)) {
            for l in 0..k {
                let v = rng.next_f32() * scale;
                for (out, keep) in outs.iter_mut().zip(keeps.iter()) {
                    if keep.contains(&i) {
                        out.data_mut()[(i - keep.start) * k + l] = v;
                    }
                }
            }
        } else {
            for _ in 0..k {
                rng.next_f32();
            }
        }
    }
    outs
}

/// Dense nonnegative low-rank + noise:
/// `M = U₀·V₀ᵀ + σ·|noise|`, entries clipped at 0.
///
/// `true_rank` controls the planted structure (≈ phenotypes / video
/// background components); `noise` the residual floor an NMF of rank
/// ≥ true_rank can reach.
pub fn low_rank_dense(
    rows: usize,
    cols: usize,
    true_rank: usize,
    noise: f32,
    rng: &mut Pcg64,
) -> Mat {
    low_rank_dense_window(rows, cols, true_rank, noise, &GenWindow::full(rows, cols), rng)
}

/// Windowed [`low_rank_dense`]: the returned block equals
/// `low_rank_dense(..).row_block(w.rows).col_block(w.cols)` bit-for-bit.
pub fn low_rank_dense_window(
    rows: usize,
    cols: usize,
    true_rank: usize,
    noise: f32,
    w: &GenWindow,
    rng: &mut Pcg64,
) -> Mat {
    low_rank_dense_windows(rows, cols, true_rank, noise, std::slice::from_ref(w), rng)
        .pop()
        .expect("one window in, one block out")
}

/// Multi-window [`low_rank_dense`]: fill every window in **one** replay of
/// the generator stream (a DSANLS rank needs both its row and its column
/// block — two independent replays would cost 2× full-generation CPU).
/// Each returned block is bit-identical to a dedicated single-window call.
///
/// The planted factors are factor-sized (`|window|×k` and full `k`-wide
/// strips), each window's product is computed directly at block shape, and
/// the noise stream is replayed entry-by-entry in global row-major order —
/// identical Box–Muller draws, with each in-window sample added to every
/// window containing it.
pub fn low_rank_dense_windows(
    rows: usize,
    cols: usize,
    true_rank: usize,
    noise: f32,
    ws: &[GenWindow],
    rng: &mut Pcg64,
) -> Vec<Mat> {
    for w in ws {
        w.validate(rows, cols);
    }
    let row_keeps: Vec<Range<usize>> = ws.iter().map(|w| w.rows.clone()).collect();
    let col_keeps: Vec<Range<usize>> = ws.iter().map(|w| w.cols.clone()).collect();
    let us = rand_uniform_row_windows(rows, true_rank, 1.0, &row_keeps, rng);
    let vs = rand_uniform_row_windows(cols, true_rank, 1.0, &col_keeps, rng);
    // Per-element GEMM accumulation runs over k in order regardless of the
    // output position, so each block product is bitwise the full-product
    // slice (asserted by data::shard tests).
    let mut ms: Vec<Mat> = us.iter().zip(vs.iter()).map(|(u, v)| u.matmul_nt(v)).collect();
    if noise > 0.0 {
        let mut g = Gaussian::new(rng.clone());
        for i in 0..rows {
            for j in 0..cols {
                let s = g.sample_f32(noise);
                for (m, w) in ms.iter_mut().zip(ws.iter()) {
                    if w.contains(i, j) {
                        let wcols = w.cols.len();
                        m.data_mut()[(i - w.rows.start) * wcols + (j - w.cols.start)] += s.abs();
                    }
                }
            }
        }
        // keep caller's rng moving
        for _ in 0..rows * cols {
            rng.next_u64();
        }
    }
    ms
}

/// Sparse power-law matrix (bag-of-words / term-document): column
/// popularity follows Zipf with exponent `zipf`, row activity is uniform;
/// values are 1 + Exp-like counts. Also plants `true_rank` soft topics so
/// NMF has structure to find.
pub fn power_law_sparse(
    rows: usize,
    cols: usize,
    nnz_target: usize,
    true_rank: usize,
    zipf: f64,
    rng: &mut Pcg64,
) -> Csr {
    let w = GenWindow::full(rows, cols);
    power_law_sparse_window(rows, cols, nnz_target, true_rank, zipf, &w, rng)
}

/// Windowed [`power_law_sparse`]: replays all `nnz_target` triplet draws
/// and keeps (rebased) only those landing inside the window. Auxiliary
/// state is one `f64` per column and one topic id per row — never the
/// matrix itself.
pub fn power_law_sparse_window(
    rows: usize,
    cols: usize,
    nnz_target: usize,
    true_rank: usize,
    zipf: f64,
    w: &GenWindow,
    rng: &mut Pcg64,
) -> Csr {
    power_law_sparse_windows(rows, cols, nnz_target, true_rank, zipf, std::slice::from_ref(w), rng)
        .pop()
        .expect("one window in, one block out")
}

/// Multi-window [`power_law_sparse`]: one replay of the triplet stream
/// fills every window (see [`low_rank_dense_windows`]).
pub fn power_law_sparse_windows(
    rows: usize,
    cols: usize,
    nnz_target: usize,
    true_rank: usize,
    zipf: f64,
    ws: &[GenWindow],
    rng: &mut Pcg64,
) -> Vec<Csr> {
    for w in ws {
        w.validate(rows, cols);
    }
    // topic model: each row gets a topic, each topic a column distribution
    // biased by Zipf rank; draws cluster within topics.
    let mut weights: Vec<f64> = (0..cols).map(|c| 1.0 / ((c + 1) as f64).powf(zipf)).collect();
    let wsum: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= wsum;
    }
    // cumulative for inverse-CDF sampling
    let mut cdf = Vec::with_capacity(cols);
    let mut acc = 0.0;
    for &w in &weights {
        acc += w;
        cdf.push(acc);
    }
    let sample_col = |r: &mut Pcg64| -> usize {
        let x = r.next_f64();
        match cdf.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cols - 1),
        }
    };

    let k = true_rank.max(1);
    let row_topic: Vec<usize> = (0..rows).map(|_| rng.below(k)).collect();
    let mut triplets: Vec<Vec<(usize, usize, f32)>> = ws
        .iter()
        .map(|w| Vec::with_capacity(w.expected_hits(rows, cols, nnz_target)))
        .collect();
    for _ in 0..nnz_target {
        let i = rng.below(rows);
        // topic shift: rotate the sampled column by a topic-dependent offset
        // so different topics emphasise different column bands
        let base = sample_col(rng);
        let j = (base + row_topic[i] * (cols / k.max(1))) % cols;
        let v = 1.0 + (rng.next_f32() * 4.0).floor(); // count-like 1..=4
        for (t, w) in triplets.iter_mut().zip(ws.iter()) {
            if w.contains(i, j) {
                t.push((i - w.rows.start, j - w.cols.start, v));
            }
        }
    }
    finish_sparse_windows(ws, triplets)
}

/// Assemble each window's rebased triplets into its CSR block.
fn finish_sparse_windows(ws: &[GenWindow], triplets: Vec<Vec<(usize, usize, f32)>>) -> Vec<Csr> {
    ws.iter()
        .zip(triplets)
        .map(|(w, t)| {
            let (wrows, wcols) = w.shape();
            Csr::from_triplets(wrows, wcols, t)
        })
        .collect()
}

/// Symmetric power-law graph adjacency (DBLP-like co-authorship):
/// preferential-attachment-flavoured edge endpoints, symmetrised.
pub fn power_law_graph(nodes: usize, edges: usize, rng: &mut Pcg64) -> Csr {
    power_law_graph_window(nodes, edges, &GenWindow::full(nodes, nodes), rng)
}

/// Windowed [`power_law_graph`]: replays every edge draw; each of the two
/// symmetric triplets is kept independently iff it lands in the window.
pub fn power_law_graph_window(
    nodes: usize,
    edges: usize,
    w: &GenWindow,
    rng: &mut Pcg64,
) -> Csr {
    power_law_graph_windows(nodes, edges, std::slice::from_ref(w), rng)
        .pop()
        .expect("one window in, one block out")
}

/// Multi-window [`power_law_graph`]: one replay of the edge stream fills
/// every window (see [`low_rank_dense_windows`]).
pub fn power_law_graph_windows(
    nodes: usize,
    edges: usize,
    ws: &[GenWindow],
    rng: &mut Pcg64,
) -> Vec<Csr> {
    for w in ws {
        w.validate(nodes, nodes);
    }
    let mut triplets: Vec<Vec<(usize, usize, f32)>> = ws
        .iter()
        .map(|w| Vec::with_capacity(w.expected_hits(nodes, nodes, edges * 2)))
        .collect();
    for _ in 0..edges {
        // endpoint ∝ (rank+1)^-0.8 via rejection-free inverse power draw
        let a = power_index(nodes, 0.8, rng);
        let b = power_index(nodes, 0.8, rng);
        if a == b {
            continue;
        }
        for (t, w) in triplets.iter_mut().zip(ws.iter()) {
            if w.contains(a, b) {
                t.push((a - w.rows.start, b - w.cols.start, 1.0));
            }
            if w.contains(b, a) {
                t.push((b - w.rows.start, a - w.cols.start, 1.0));
            }
        }
    }
    finish_sparse_windows(ws, triplets)
}

fn power_index(n: usize, alpha: f64, rng: &mut Pcg64) -> usize {
    // inverse-CDF of p(i) ∝ (i+1)^(−alpha) approximated by u^(1/(1−alpha))
    let u = rng.next_f64().max(1e-12);
    let x = u.powf(1.0 / (1.0 - alpha));
    ((x * n as f64) as usize).min(n - 1)
}

/// MNIST-like: blocky nonnegative "digit strokes" with ~20 % density.
/// Rows = images (mixtures of `true_rank` stroke templates), cols = pixels.
pub fn blocky_sparse(
    rows: usize,
    cols: usize,
    true_rank: usize,
    density: f64,
    rng: &mut Pcg64,
) -> Csr {
    blocky_sparse_window(rows, cols, true_rank, density, &GenWindow::full(rows, cols), rng)
}

/// Windowed [`blocky_sparse`]: out-of-window rows still consume their
/// (data-dependent) share of the random stream, they just don't emit
/// triplets.
pub fn blocky_sparse_window(
    rows: usize,
    cols: usize,
    true_rank: usize,
    density: f64,
    w: &GenWindow,
    rng: &mut Pcg64,
) -> Csr {
    blocky_sparse_windows(rows, cols, true_rank, density, std::slice::from_ref(w), rng)
        .pop()
        .expect("one window in, one block out")
}

/// Multi-window [`blocky_sparse`]: one replay of the stroke stream fills
/// every window (see [`low_rank_dense_windows`]).
pub fn blocky_sparse_windows(
    rows: usize,
    cols: usize,
    true_rank: usize,
    density: f64,
    ws: &[GenWindow],
    rng: &mut Pcg64,
) -> Vec<Csr> {
    for w in ws {
        w.validate(rows, cols);
    }
    // templates: each covers a contiguous band of pixels
    let k = true_rank.max(1);
    let band = (cols as f64 * density * 2.0).ceil() as usize;
    let band = band.clamp(1, cols);
    let mut triplets: Vec<Vec<(usize, usize, f32)>> = ws.iter().map(|_| Vec::new()).collect();
    for i in 0..rows {
        // each image mixes 1–3 templates
        let n_tpl = 1 + rng.below(3);
        for _ in 0..n_tpl {
            let t = rng.below(k);
            let start = (t * cols / k) % cols;
            // within the band, keep ~half the pixels
            for j in 0..band {
                if rng.next_f32() < 0.5 {
                    let col = (start + j) % cols;
                    let v = 0.2 + rng.next_f32();
                    for (tr, w) in triplets.iter_mut().zip(ws.iter()) {
                        if w.contains(i, col) {
                            tr.push((i - w.rows.start, col - w.cols.start, v));
                        }
                    }
                }
            }
        }
    }
    finish_sparse_windows(ws, triplets)
}

/// Wrap a generator output in [`Matrix`], choosing dense/sparse storage by
/// the achieved density.
pub fn auto_storage(m: Csr) -> Matrix {
    if m.density() > 0.5 {
        Matrix::Dense(m.to_dense())
    } else {
        Matrix::Sparse(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_rank_is_nonnegative_and_low_rank() {
        let mut rng = Pcg64::new(100, 0);
        let m = low_rank_dense(30, 20, 3, 0.01, &mut rng);
        assert!(m.is_nonnegative());
        // rank-3 NMF should reach small error
        let f = crate::nmf::Anls::new(crate::nmf::AnlsOptions {
            rank: 3,
            iterations: 60,
            solver: crate::solvers::SolverKind::Hals,
            inner_sweeps: 2,
            ..Default::default()
        })
        .run(&Matrix::Dense(m));
        assert!(f.final_error() < 0.12, "err = {}", f.final_error());
    }

    #[test]
    fn power_law_sparse_hits_density() {
        let mut rng = Pcg64::new(101, 0);
        let m = power_law_sparse(500, 300, 6000, 5, 1.1, &mut rng);
        assert_eq!(m.rows(), 500);
        assert!(m.nnz() > 4000, "nnz = {}", m.nnz());
        assert!(m.density() < 0.05);
        assert!(m.values().iter().all(|&v| v >= 1.0));
    }

    #[test]
    fn graph_is_symmetric() {
        let mut rng = Pcg64::new(102, 0);
        let g = power_law_graph(100, 400, &mut rng);
        let d = g.to_dense();
        for i in 0..100 {
            for j in 0..100 {
                assert_eq!(d.get(i, j), d.get(j, i));
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let gen = || {
            let mut rng = Pcg64::new(103, 0);
            power_law_sparse(100, 80, 800, 4, 1.0, &mut rng)
        };
        assert_eq!(gen().values(), gen().values());
    }

    #[test]
    fn blocky_has_reasonable_density() {
        let mut rng = Pcg64::new(104, 0);
        let m = blocky_sparse(200, 196, 8, 0.2, &mut rng);
        let d = m.density();
        assert!(d > 0.02 && d < 0.6, "density {d}");
    }

    #[test]
    fn windowed_generation_equals_full_slice() {
        // every generator, a strict interior window on both axes
        let w = GenWindow { rows: 13..41, cols: 7..29 };

        let full = {
            let mut rng = Pcg64::new(900, 0);
            low_rank_dense(60, 40, 4, 0.02, &mut rng)
        };
        let block = {
            let mut rng = Pcg64::new(900, 0);
            low_rank_dense_window(60, 40, 4, 0.02, &w, &mut rng)
        };
        assert_eq!(full.row_block(w.rows.clone()).col_block(w.cols.clone()), block);

        let full = {
            let mut rng = Pcg64::new(901, 0);
            power_law_sparse(60, 40, 900, 4, 1.0, &mut rng)
        };
        let block = {
            let mut rng = Pcg64::new(901, 0);
            power_law_sparse_window(60, 40, 900, 4, 1.0, &w, &mut rng)
        };
        assert_eq!(full.row_block(w.rows.clone()).col_block(w.cols.clone()), block);

        let full = {
            let mut rng = Pcg64::new(902, 0);
            power_law_graph(60, 400, &mut rng)
        };
        let block = {
            let mut rng = Pcg64::new(902, 0);
            power_law_graph_window(60, 400, &w, &mut rng)
        };
        assert_eq!(full.row_block(w.rows.clone()).col_block(w.cols.clone()), block);

        let full = {
            let mut rng = Pcg64::new(903, 0);
            blocky_sparse(60, 40, 5, 0.2, &mut rng)
        };
        let block = {
            let mut rng = Pcg64::new(903, 0);
            blocky_sparse_window(60, 40, 5, 0.2, &w, &mut rng)
        };
        assert_eq!(full.row_block(w.rows.clone()).col_block(w.cols.clone()), block);
    }

    #[test]
    fn multi_window_single_pass_matches_two_pass() {
        // the single-pass dual-window fill must be bit-identical to two
        // independent replays (one per window) — the shard data plane's
        // row-block + column-block shape
        let w1 = GenWindow { rows: 10..30, cols: 0..40 }; // row-block style
        let w2 = GenWindow { rows: 0..60, cols: 12..25 }; // col-block style
        let ws = [w1.clone(), w2.clone()];

        let both = {
            let mut rng = Pcg64::new(920, 0);
            low_rank_dense_windows(60, 40, 4, 0.03, &ws, &mut rng)
        };
        let mut rng = Pcg64::new(920, 0);
        assert_eq!(both[0], low_rank_dense_window(60, 40, 4, 0.03, &w1, &mut rng));
        let mut rng = Pcg64::new(920, 0);
        assert_eq!(both[1], low_rank_dense_window(60, 40, 4, 0.03, &w2, &mut rng));

        let both = {
            let mut rng = Pcg64::new(921, 0);
            power_law_sparse_windows(60, 40, 900, 4, 1.0, &ws, &mut rng)
        };
        let mut rng = Pcg64::new(921, 0);
        assert_eq!(both[0], power_law_sparse_window(60, 40, 900, 4, 1.0, &w1, &mut rng));
        let mut rng = Pcg64::new(921, 0);
        assert_eq!(both[1], power_law_sparse_window(60, 40, 900, 4, 1.0, &w2, &mut rng));

        let sq = [
            GenWindow { rows: 10..30, cols: 0..60 },
            GenWindow { rows: 0..60, cols: 12..25 },
        ];
        let both = {
            let mut rng = Pcg64::new(922, 0);
            power_law_graph_windows(60, 400, &sq, &mut rng)
        };
        let mut rng = Pcg64::new(922, 0);
        assert_eq!(both[0], power_law_graph_window(60, 400, &sq[0], &mut rng));
        let mut rng = Pcg64::new(922, 0);
        assert_eq!(both[1], power_law_graph_window(60, 400, &sq[1], &mut rng));

        let both = {
            let mut rng = Pcg64::new(923, 0);
            blocky_sparse_windows(60, 40, 5, 0.2, &ws, &mut rng)
        };
        let mut rng = Pcg64::new(923, 0);
        assert_eq!(both[0], blocky_sparse_window(60, 40, 5, 0.2, &w1, &mut rng));
        let mut rng = Pcg64::new(923, 0);
        assert_eq!(both[1], blocky_sparse_window(60, 40, 5, 0.2, &w2, &mut rng));
    }

    #[test]
    fn window_advances_caller_rng_like_full() {
        // after generation, the caller's rng must be in the same state no
        // matter which window was drawn (shared-seed contract)
        let w = GenWindow { rows: 0..10, cols: 0..40 };
        let mut a = Pcg64::new(910, 0);
        let mut b = Pcg64::new(910, 0);
        let _ = low_rank_dense(60, 40, 4, 0.05, &mut a);
        let _ = low_rank_dense_window(60, 40, 4, 0.05, &w, &mut b);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
