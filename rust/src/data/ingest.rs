//! External matrix ingestion: a simple COO text / MatrixMarket-style
//! reader, so `dsanls shard --input FILE` can pre-slice a *real* matrix
//! instead of the synthetic Table-1 generators.
//!
//! ## Accepted format
//!
//! * An optional `%%MatrixMarket matrix coordinate <field> general` banner
//!   on the first line. With the banner, entry indices are **1-based**
//!   (the MatrixMarket convention) and `<field>` may be `real`, `integer`
//!   or `pattern` (pattern entries carry no value and are read as `1.0`).
//!   Only `general` symmetry is supported.
//! * Comment lines starting with `%` or `#` (anywhere), blank lines
//!   ignored.
//! * The first non-comment line is the header: `rows cols nnz`.
//! * Then exactly `nnz` entry lines: `row col value` (`row col` for
//!   pattern files). Without a banner, indices are **0-based**.
//!
//! Values must be finite and nonnegative (NMF input); duplicates are
//! summed ([`crate::linalg::Csr::from_triplets`]). Every malformed input —
//! truncated file, missing header, out-of-range index, negative or
//! unparsable value — is a typed [`crate::error::Error`] naming the
//! offending line, never a panic.
//!
//! ## Streaming shard ingestion
//!
//! [`shard_stream`] is the production path behind `dsanls shard --input`:
//! it reads the file **line by line in a single pass**, bucketing each
//! COO entry straight into its owning rank's row-block and column-block
//! triplet buckets — the full matrix structure is **never materialised**
//! (the old path built the complete `Matrix` first, which made the shard
//! CLI the memory ceiling for real inputs). Peak residency is the raw
//! triplets (each entry appears in one row bucket and one col bucket)
//! plus a single block under construction. The output is **bit-identical**
//! to materialise-then-[`crate::data::shard::write_shard_dir`]: blocks
//! sort/merge per bucket exactly as a global CSR build would, the exact
//! `‖M‖²_F` is chained across row blocks in storage order
//! (associativity-free, like [`crate::data::shard::exact_fro_sq`]), and
//! the dense/sparse storage decision uses the same achieved-density rule
//! as [`crate::data::synth::auto_storage`] — asserted byte-for-byte by
//! the module tests.

use std::io::BufRead;
use std::path::Path;

use crate::data::partition::{uniform_partition, weight_balanced_partition, Partition};
use crate::data::shard::{self, file_dataset_name, Axis, ShardManifest, ShardSpec};
use crate::data::synth::auto_storage;
use crate::error::{Context, Result};
use crate::linalg::{Csr, Matrix};

/// Parsed `rows cols nnz` header (plus the banner's index convention).
#[derive(Debug, Clone, Copy)]
struct CooHeader {
    rows: usize,
    cols: usize,
    nnz: usize,
}

/// Stream a COO text / `.mtx`-style file from `r`: `on_header` fires once
/// when the `rows cols nnz` header is parsed (so the caller can size its
/// buckets), then `sink` fires once per entry **in file order** — the
/// single-pass core both [`parse_coo`] (materialise) and [`shard_stream`]
/// (bucket per shard) are built on. Errors carry the 1-based line number
/// of the offence.
fn parse_stream<R: BufRead>(
    r: R,
    on_header: &mut dyn FnMut(CooHeader),
    sink: &mut dyn FnMut(usize, usize, f32) -> Result<()>,
) -> Result<CooHeader> {
    let mut lines = r.lines().enumerate();

    // --- optional MatrixMarket banner on the very first line ---
    let mut one_based = false;
    let mut pattern = false;
    let mut header: Option<(usize, String)> = None;
    for (no, raw) in lines.by_ref() {
        let raw = raw.with_context(|| format!("line {}: read failed", no + 1))?;
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(banner) = line.strip_prefix("%%") {
            let b = banner.to_ascii_lowercase();
            if !b.starts_with("matrixmarket") {
                crate::bail!("line {}: unknown %% banner {line:?}", no + 1);
            }
            if !b.contains("matrix") || !b.contains("coordinate") {
                crate::bail!(
                    "line {}: only `matrix coordinate` MatrixMarket files are supported",
                    no + 1
                );
            }
            if !b.contains("general") {
                crate::bail!(
                    "line {}: only `general` symmetry is supported (got {line:?})",
                    no + 1
                );
            }
            one_based = true;
            pattern = b.contains("pattern");
            continue;
        }
        if line.starts_with('%') || line.starts_with('#') {
            continue;
        }
        header = Some((no, line.to_string()));
        break;
    }
    let (hline, htext) = header.context("no header line (`rows cols nnz`) before end of file")?;
    let hf: Vec<&str> = htext.split_whitespace().collect();
    if hf.len() != 3 {
        crate::bail!("line {}: header must be `rows cols nnz`, got {htext:?}", hline + 1);
    }
    let parse_dim = |s: &str, what: &str| -> Result<usize> {
        s.parse::<usize>()
            .map_err(|e| crate::err!("line {}: bad {what} {s:?}: {e}", hline + 1))
    };
    let rows = parse_dim(hf[0], "row count")?;
    let cols = parse_dim(hf[1], "column count")?;
    let nnz = parse_dim(hf[2], "entry count")?;
    if rows == 0 || cols == 0 {
        crate::bail!("line {}: empty matrix ({rows}x{cols})", hline + 1);
    }
    on_header(CooHeader { rows, cols, nnz });

    // --- entries ---
    let base = usize::from(one_based);
    let mut seen = 0usize;
    for (no, raw) in lines {
        let raw = raw.with_context(|| format!("line {}: read failed", no + 1))?;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('%') || line.starts_with('#') {
            continue;
        }
        if seen == nnz {
            crate::bail!("line {}: more than the {nnz} entries the header declared", no + 1);
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        let value = match (f.len(), pattern) {
            (2, true) => 1.0f32,
            (3, false) => {
                let v = f[2]
                    .parse::<f32>()
                    .map_err(|e| crate::err!("line {}: bad value {:?}: {e}", no + 1, f[2]))?;
                if !v.is_finite() {
                    crate::bail!("line {}: non-finite value {v}", no + 1);
                }
                if v < 0.0 {
                    crate::bail!("line {}: negative value {v} (NMF input must be ≥ 0)", no + 1);
                }
                v
            }
            _ => crate::bail!(
                "line {}: expected `row col{}` ({} fields), got {line:?}",
                no + 1,
                if pattern { "" } else { " value" },
                if pattern { 2 } else { 3 }
            ),
        };
        let idx = |s: &str, extent: usize, what: &str| -> Result<usize> {
            let i = s
                .parse::<usize>()
                .map_err(|e| crate::err!("line {}: bad {what} index {s:?}: {e}", no + 1))?;
            let i = i
                .checked_sub(base)
                .with_context(|| format!("line {}: {what} index 0 in a 1-based file", no + 1))?;
            if i >= extent {
                crate::bail!(
                    "line {}: {what} index {i} outside 0..{extent} (after {}-based adjustment)",
                    no + 1,
                    base
                );
            }
            Ok(i)
        };
        let r = idx(f[0], rows, "row")?;
        let c = idx(f[1], cols, "column")?;
        sink(r, c, value)?;
        seen += 1;
    }
    if seen != nnz {
        crate::bail!(
            "file ends after {seen} entries but the header declared {nnz} (truncated file?)"
        );
    }
    Ok(CooHeader { rows, cols, nnz })
}

/// Load a COO text / `.mtx`-style matrix file (see the module docs for the
/// format) into a materialised [`Matrix`]. Storage (dense vs CSR) is
/// chosen by the achieved density, like the synthetic generators. For
/// sharding large files prefer [`shard_stream`], which never builds the
/// full matrix.
pub fn load_matrix(path: &Path) -> Result<Matrix> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("reading matrix file {}", path.display()))?;
    parse_reader(std::io::BufReader::new(file))
        .with_context(|| format!("parsing matrix file {}", path.display()))
}

/// Parse COO text (the testable core of [`load_matrix`]).
pub fn parse_coo(text: &str) -> Result<Matrix> {
    parse_reader(std::io::Cursor::new(text))
}

fn parse_reader<R: BufRead>(r: R) -> Result<Matrix> {
    let mut triplets: Vec<(usize, usize, f32)> = Vec::new();
    let header = parse_stream(
        r,
        &mut |h| triplets.reserve(h.nnz),
        &mut |i, j, v| {
            triplets.push((i, j, v));
            Ok(())
        },
    )?;
    Ok(auto_storage(Csr::from_triplets(header.rows, header.cols, triplets)))
}

/// How `dsanls shard` cuts the column axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardBalance {
    /// Equal column *counts* per rank (the default).
    #[default]
    Uniform,
    /// Equal stored-value counts per rank
    /// ([`weight_balanced_partition`] over per-column nnz) — the
    /// skew-aware layout for the secure protocols.
    Nnz,
}

/// Pre-slice an external COO/`.mtx` file into a shard directory in a
/// **chunked single pass** (see the module docs): stream entries into
/// per-rank row/column buckets, build and write one block at a time, and
/// record the chained exact `‖M‖²_F` plus both partitions in the
/// manifest. Row cuts are always uniform (the chain reduction and the
/// non-secure algorithms assume them); `balance` controls the column
/// cuts. Returns the manifest and total bytes written.
pub fn shard_stream(
    path: &Path,
    out: &Path,
    nodes: usize,
    balance: ShardBalance,
    seed: u64,
    scale: f64,
) -> Result<(ShardManifest, u64)> {
    assert!(nodes >= 1, "shard_stream needs at least one rank");
    let file = std::fs::File::open(path)
        .with_context(|| format!("reading matrix file {}", path.display()))?;
    let reader = std::io::BufReader::new(file);

    // ---- the single pass: bucket every entry by its row-block owner ----
    // (column blocks are re-bucketed from the already-merged row blocks
    // below, so the file is read exactly once; entries keep file order
    // inside a bucket, which is what makes duplicate-merge order — and
    // therefore the float sums — identical to a global CSR build)
    let owner = |bounds: &[usize], i: usize| -> usize {
        // bounds are sorted cut points [0, b1, …, total]
        bounds.partition_point(|&b| b <= i).saturating_sub(1).min(bounds.len() - 2)
    };
    // shared by the header hook (which sizes it) and the entry sink (which
    // fills it) — a RefCell because parse_stream takes the two callbacks
    // as independent mutable borrows
    struct StreamState {
        row_bounds: Vec<usize>,
        row_buckets: Vec<Vec<(usize, usize, f32)>>,
    }
    let state = std::cell::RefCell::new(StreamState {
        row_bounds: Vec::new(),
        row_buckets: Vec::new(),
    });
    let header = parse_stream(
        reader,
        &mut |h| {
            let mut s = state.borrow_mut();
            s.row_bounds = uniform_partition(h.rows, nodes).bounds();
            s.row_buckets = (0..nodes).map(|_| Vec::new()).collect();
        },
        &mut |i, j, v| {
            let mut s = state.borrow_mut();
            let r = owner(&s.row_bounds, i);
            let base = s.row_bounds[r];
            s.row_buckets[r].push((i - base, j, v));
            Ok(())
        },
    )
    .with_context(|| format!("parsing matrix file {}", path.display()))?;
    let StreamState { row_bounds, row_buckets } = state.into_inner();
    let (rows, cols) = (header.rows, header.cols);
    let row_part = Partition::from_bounds(&row_bounds).expect("uniform bounds are well-formed");

    // ---- build row blocks rank by rank: merged nnz + chained exact ‖M‖² ----
    let row_ranges: Vec<std::ops::Range<usize>> = (0..nodes).map(|r| row_part.range(r)).collect();
    let mut row_csrs: Vec<Csr> = Vec::with_capacity(nodes);
    let mut merged_nnz = 0usize;
    let mut fro_acc = 0.0f64;
    for (r, bucket) in row_buckets.into_iter().enumerate() {
        let csr = Csr::from_triplets(row_ranges[r].len(), cols, bucket);
        merged_nnz += csr.nnz();
        // rank-ordered row blocks concatenate to the full storage order,
        // so resuming the sequential fold reproduces Matrix::fro_sq bit-
        // for-bit (the same argument as shard::exact_fro_sq)
        fro_acc = csr.values().iter().fold(fro_acc, |a, &v| a + (v as f64) * (v as f64));
        row_csrs.push(csr);
    }
    let dense = merged_nnz as f64 / (rows as f64 * cols as f64) > 0.5;

    // ---- column partition: uniform, or nnz-balanced from the counts ----
    // weights come from the MERGED row blocks (duplicates collapse before
    // they are weighed), matching the generator path's col_nnz_counts
    let col_part = match balance {
        ShardBalance::Uniform => uniform_partition(cols, nodes),
        ShardBalance::Nnz => {
            let mut col_counts = vec![0usize; cols];
            for csr in &row_csrs {
                for &j in csr.indices() {
                    col_counts[j] += 1;
                }
            }
            weight_balanced_partition(&col_counts, nodes)
        }
    };

    // ---- write: manifest, then one block at a time ----
    let manifest = ShardManifest {
        nodes,
        rows,
        cols,
        fro_sq: fro_acc,
        seed,
        scale,
        dense,
        dataset: file_dataset_name(path),
        row_bounds: row_part.bounds(),
        col_bounds: col_part.bounds(),
    };
    std::fs::create_dir_all(out)
        .with_context(|| format!("creating shard directory {}", out.display()))?;
    let mut total = shard::write_manifest(out, &manifest)?;
    // per rank: scatter this row block's (already-merged, sorted) entries
    // into the column buckets, write the row block, and DROP it before
    // touching the next — the data is never resident three times (row
    // CSRs + full col buckets + block) at once
    let col_bounds = col_part.bounds();
    let mut col_buckets: Vec<Vec<(usize, usize, f32)>> = (0..nodes).map(|_| Vec::new()).collect();
    for (r, csr) in row_csrs.into_iter().enumerate() {
        let base = row_ranges[r].start;
        for i in 0..csr.rows() {
            for (j, v) in csr.row_iter(i) {
                let owner_rank = owner(&col_bounds, j);
                col_buckets[owner_rank].push((base + i, j - col_bounds[owner_rank], v));
            }
        }
        let spec =
            ShardSpec { rank: r, nodes, axis: Axis::Row, range: row_ranges[r].clone() };
        let block =
            if dense { Matrix::Dense(csr.to_dense()) } else { Matrix::Sparse(csr) };
        total += shard::write_block(out, &spec, &block)?;
    }
    for (r, bucket) in col_buckets.into_iter().enumerate() {
        let range = col_part.range(r);
        let csr = Csr::from_triplets(rows, range.len(), bucket);
        let spec = ShardSpec { rank: r, nodes, axis: Axis::Col, range };
        let block =
            if dense { Matrix::Dense(csr.to_dense()) } else { Matrix::Sparse(csr) };
        total += shard::write_block(out, &spec, &block)?;
    }
    Ok((manifest, total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shard::{block_path, matrix_bits_eq, read_manifest, write_shard_dir, NodeData};
    use std::path::PathBuf;

    #[test]
    fn plain_coo_roundtrip() {
        let m = parse_coo("# sparse 3x4\n3 4 3\n0 0 1.5\n2 3 2.0\n1 1 0.25\n").unwrap();
        assert_eq!((m.rows(), m.cols()), (3, 4));
        assert_eq!(m.nnz(), 3);
        match &m {
            Matrix::Sparse(s) => {
                let d = s.to_dense();
                assert_eq!(d.get(0, 0), 1.5);
                assert_eq!(d.get(2, 3), 2.0);
                assert_eq!(d.get(1, 1), 0.25);
            }
            Matrix::Dense(_) => panic!("3 of 12 entries must stay sparse"),
        }
    }

    #[test]
    fn matrix_market_one_based_and_pattern() {
        let real = "%%MatrixMarket matrix coordinate real general\n% comment\n2 2 2\n1 1 3.0\n2 2 4.0\n";
        let m = parse_coo(real).unwrap();
        assert_eq!(m.nnz(), 2);
        let d = match &m {
            Matrix::Dense(d) => d.clone(),
            Matrix::Sparse(s) => s.to_dense(),
        };
        assert_eq!((d.get(0, 0), d.get(1, 1)), (3.0, 4.0));

        let pat = "%%MatrixMarket matrix coordinate pattern general\n2 3 2\n1 3\n2 1\n";
        let m = parse_coo(pat).unwrap();
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn duplicates_are_summed() {
        let m = parse_coo("2 2 2\n0 1 1.0\n0 1 2.5\n").unwrap();
        assert_eq!(m.nnz(), 1, "duplicates must merge");
        if let Matrix::Sparse(s) = &m {
            assert_eq!(s.values(), &[3.5]);
        }
    }

    #[test]
    fn dense_storage_for_dense_files() {
        let mut text = String::from("2 2 4\n");
        for r in 0..2 {
            for c in 0..2 {
                text.push_str(&format!("{r} {c} 1.0\n"));
            }
        }
        assert!(matches!(parse_coo(&text).unwrap(), Matrix::Dense(_)));
    }

    #[test]
    fn malformed_inputs_error_cleanly() {
        for (tag, text) in [
            ("empty", ""),
            ("comment only", "# nothing\n% here\n"),
            ("short header", "3 4\n"),
            ("bad header token", "3 x 2\n0 0 1\n0 1 1\n"),
            ("zero dims", "0 4 0\n"),
            ("bad value", "2 2 1\n0 0 abc\n"),
            ("negative value", "2 2 1\n0 0 -1.0\n"),
            ("non-finite value", "2 2 1\n0 0 inf\n"),
            ("row out of range", "2 2 1\n2 0 1.0\n"),
            ("col out of range", "2 2 1\n0 5 1.0\n"),
            ("truncated entries", "2 2 3\n0 0 1.0\n"),
            ("extra entries", "2 2 1\n0 0 1.0\n1 1 1.0\n"),
            ("two fields no pattern", "2 2 1\n0 0\n"),
            ("symmetric banner", "%%MatrixMarket matrix coordinate real symmetric\n2 2 1\n1 1 1\n"),
            ("array banner", "%%MatrixMarket matrix array real general\n2 2 1\n1 1 1\n"),
            ("unknown banner", "%%NotMatrixMarket\n2 2 1\n0 0 1\n"),
            ("one-based zero index", "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1\n"),
        ] {
            let r = parse_coo(text);
            assert!(r.is_err(), "{tag}: malformed input must error");
        }
    }

    #[test]
    fn load_matrix_io_error_has_context() {
        let err = load_matrix(Path::new("/definitely/not/here.mtx")).unwrap_err();
        assert!(err.to_string().contains("matrix file"), "{err}");
    }

    // -----------------------------------------------------------------
    // streaming shard ingestion
    // -----------------------------------------------------------------

    fn tmpbase(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dsanls_ingest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A sparse file with duplicates and skewed columns, exercising both
    /// the merge order and the balance path.
    fn skewed_coo_text(rows: usize, cols: usize) -> String {
        let mut text = String::new();
        let mut entries = Vec::new();
        for i in 0..rows {
            // column 0 and 1 are heavy; a few spread entries; one duplicate
            entries.push((i, 0, 1.0 + i as f32 * 0.25));
            entries.push((i, 1, 0.5 + i as f32 * 0.125));
            entries.push((i, (i * 7) % cols, 2.0 + i as f32 * 0.0625));
            if i % 5 == 0 {
                entries.push((i, 0, 0.375)); // duplicate of a heavy cell
            }
        }
        text.push_str(&format!("{rows} {cols} {}\n", entries.len()));
        for (r, c, v) in entries {
            text.push_str(&format!("{r} {c} {v}\n"));
        }
        text
    }

    /// The single-pass streamed shard directory must be **byte-identical**
    /// to the legacy materialise-then-slice path (same manifest, same
    /// block files), duplicates and all.
    #[test]
    fn shard_stream_bit_identical_to_materialised_path() {
        let base = tmpbase("bitident");
        let coo = base.join("skewed.coo");
        std::fs::write(&coo, skewed_coo_text(23, 17)).unwrap();
        for nodes in [1usize, 3] {
            // legacy path: full matrix, then write_shard_dir
            let m = load_matrix(&coo).unwrap();
            let old_dir = base.join(format!("old{nodes}"));
            let manifest = ShardManifest::uniform(
                nodes,
                m.rows(),
                m.cols(),
                m.fro_sq(),
                7,
                1.5,
                matches!(m, Matrix::Dense(_)),
                file_dataset_name(&coo),
            );
            write_shard_dir(&old_dir, &m, &manifest).unwrap();

            // streaming path
            let new_dir = base.join(format!("new{nodes}"));
            let (streamed, _) =
                shard_stream(&coo, &new_dir, nodes, ShardBalance::Uniform, 7, 1.5).unwrap();
            assert_eq!(streamed, manifest, "manifests diverged");
            assert_eq!(
                std::fs::read(crate::data::shard::manifest_path(&old_dir)).unwrap(),
                std::fs::read(crate::data::shard::manifest_path(&new_dir)).unwrap(),
                "manifest bytes diverged"
            );
            for rank in 0..nodes {
                for axis in [Axis::Row, Axis::Col] {
                    let a = std::fs::read(block_path(&old_dir, rank, axis)).unwrap();
                    let b = std::fs::read(block_path(&new_dir, rank, axis)).unwrap();
                    assert_eq!(a, b, "rank {rank} {axis:?} block bytes diverged ({nodes} nodes)");
                }
            }
        }
        std::fs::remove_dir_all(&base).ok();
    }

    /// A dense-majority file must stream to dense blocks identical to the
    /// legacy path (the achieved-density rule is shared).
    #[test]
    fn shard_stream_matches_dense_storage_decision() {
        let base = tmpbase("dense");
        let coo = base.join("dense.coo");
        let mut text = String::from("4 4 14\n");
        for i in 0..4 {
            for j in 0..4 {
                if (i, j) != (3, 3) && (i, j) != (0, 3) {
                    text.push_str(&format!("{i} {j} {}.5\n", i + j));
                }
            }
        }
        std::fs::write(&coo, text).unwrap();
        let m = load_matrix(&coo).unwrap();
        assert!(matches!(m, Matrix::Dense(_)), "14/16 entries should go dense");
        let dir = base.join("shards");
        let (manifest, _) = shard_stream(&coo, &dir, 2, ShardBalance::Uniform, 0, 1.0).unwrap();
        assert!(manifest.dense);
        let (data, _) = NodeData::load(&dir, 0, true, true).unwrap();
        assert!(matrix_bits_eq(
            &m.row_block(manifest.row_partition().range(0)),
            data.require_rows()
        ));
        assert_eq!(data.fro_sq().to_bits(), m.fro_sq().to_bits(), "chained ‖M‖² must be exact");
        std::fs::remove_dir_all(&base).ok();
    }

    /// `--balance nnz` ingestion: the manifest records skew-aware column
    /// cuts and per-rank resident nnz evens out on a skewed file.
    #[test]
    fn shard_stream_balances_column_nnz() {
        let base = tmpbase("balance");
        let coo = base.join("skewed.coo");
        std::fs::write(&coo, skewed_coo_text(60, 30)).unwrap();
        let dir = base.join("shards");
        let (manifest, _) = shard_stream(&coo, &dir, 3, ShardBalance::Nnz, 0, 1.0).unwrap();
        assert!(manifest.is_balanced(), "nnz balance should move the cuts on this input");
        let back = read_manifest(&dir).unwrap();
        assert_eq!(back.col_bounds, manifest.col_bounds);
        let nnz: Vec<usize> = (0..3)
            .map(|r| NodeData::load(&dir, r, false, true).unwrap().0.nnz())
            .collect();
        let (lo, hi) = (*nnz.iter().min().unwrap(), *nnz.iter().max().unwrap());
        assert!((hi as f64) < 2.0 * lo.max(1) as f64, "balanced col nnz spread too wide: {nnz:?}");
        std::fs::remove_dir_all(&base).ok();
    }

    /// Streaming ingestion keeps the line-numbered typed errors.
    #[test]
    fn shard_stream_reports_offending_line() {
        let base = tmpbase("err");
        let coo = base.join("bad.coo");
        std::fs::write(&coo, "4 3 5\n0 0 1.0\n9 9 2.0\n").unwrap();
        let err = shard_stream(&coo, &base.join("s"), 2, ShardBalance::Uniform, 0, 1.0)
            .unwrap_err();
        assert!(err.to_string().contains("line 3"), "error should name the line: {err}");
        std::fs::remove_dir_all(&base).ok();
    }
}
