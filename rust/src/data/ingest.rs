//! External matrix ingestion: a simple COO text / MatrixMarket-style
//! reader, so `dsanls shard --input FILE` can pre-slice a *real* matrix
//! instead of the synthetic Table-1 generators.
//!
//! ## Accepted format
//!
//! * An optional `%%MatrixMarket matrix coordinate <field> general` banner
//!   on the first line. With the banner, entry indices are **1-based**
//!   (the MatrixMarket convention) and `<field>` may be `real`, `integer`
//!   or `pattern` (pattern entries carry no value and are read as `1.0`).
//!   Only `general` symmetry is supported.
//! * Comment lines starting with `%` or `#` (anywhere), blank lines
//!   ignored.
//! * The first non-comment line is the header: `rows cols nnz`.
//! * Then exactly `nnz` entry lines: `row col value` (`row col` for
//!   pattern files). Without a banner, indices are **0-based**.
//!
//! Values must be finite and nonnegative (NMF input); duplicates are
//! summed ([`crate::linalg::Csr::from_triplets`]). Every malformed input —
//! truncated file, missing header, out-of-range index, negative or
//! unparsable value — is a typed [`crate::error::Error`] naming the
//! offending line, never a panic.

use std::path::Path;

use crate::data::synth::auto_storage;
use crate::error::{Context, Result};
use crate::linalg::{Csr, Matrix};

/// Load a COO text / `.mtx`-style matrix file (see the module docs for the
/// format). Storage (dense vs CSR) is chosen by the achieved density, like
/// the synthetic generators.
pub fn load_matrix(path: &Path) -> Result<Matrix> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading matrix file {}", path.display()))?;
    parse_coo(&text).with_context(|| format!("parsing matrix file {}", path.display()))
}

/// Parse COO text (the testable core of [`load_matrix`]).
pub fn parse_coo(text: &str) -> Result<Matrix> {
    let mut lines = text.lines().enumerate();

    // --- optional MatrixMarket banner on the very first line ---
    let mut one_based = false;
    let mut pattern = false;
    let mut header: Option<(usize, &str)> = None;
    for (no, raw) in lines.by_ref() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(banner) = line.strip_prefix("%%") {
            let b = banner.to_ascii_lowercase();
            if !b.starts_with("matrixmarket") {
                crate::bail!("line {}: unknown %% banner {line:?}", no + 1);
            }
            if !b.contains("matrix") || !b.contains("coordinate") {
                crate::bail!(
                    "line {}: only `matrix coordinate` MatrixMarket files are supported",
                    no + 1
                );
            }
            if !b.contains("general") {
                crate::bail!(
                    "line {}: only `general` symmetry is supported (got {line:?})",
                    no + 1
                );
            }
            one_based = true;
            pattern = b.contains("pattern");
            continue;
        }
        if line.starts_with('%') || line.starts_with('#') {
            continue;
        }
        header = Some((no, line));
        break;
    }
    let (hline, htext) = header.context("no header line (`rows cols nnz`) before end of file")?;
    let hf: Vec<&str> = htext.split_whitespace().collect();
    if hf.len() != 3 {
        crate::bail!("line {}: header must be `rows cols nnz`, got {htext:?}", hline + 1);
    }
    let parse_dim = |s: &str, what: &str| -> Result<usize> {
        s.parse::<usize>()
            .map_err(|e| crate::err!("line {}: bad {what} {s:?}: {e}", hline + 1))
    };
    let rows = parse_dim(hf[0], "row count")?;
    let cols = parse_dim(hf[1], "column count")?;
    let nnz = parse_dim(hf[2], "entry count")?;
    if rows == 0 || cols == 0 {
        crate::bail!("line {}: empty matrix ({rows}x{cols})", hline + 1);
    }

    // --- entries ---
    let base = usize::from(one_based);
    let mut triplets: Vec<(usize, usize, f32)> = Vec::with_capacity(nnz);
    for (no, raw) in lines {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('%') || line.starts_with('#') {
            continue;
        }
        if triplets.len() == nnz {
            crate::bail!("line {}: more than the {nnz} entries the header declared", no + 1);
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        let value = match (f.len(), pattern) {
            (2, true) => 1.0f32,
            (3, false) => {
                let v = f[2]
                    .parse::<f32>()
                    .map_err(|e| crate::err!("line {}: bad value {:?}: {e}", no + 1, f[2]))?;
                if !v.is_finite() {
                    crate::bail!("line {}: non-finite value {v}", no + 1);
                }
                if v < 0.0 {
                    crate::bail!("line {}: negative value {v} (NMF input must be ≥ 0)", no + 1);
                }
                v
            }
            _ => crate::bail!(
                "line {}: expected `row col{}` ({} fields), got {line:?}",
                no + 1,
                if pattern { "" } else { " value" },
                if pattern { 2 } else { 3 }
            ),
        };
        let idx = |s: &str, extent: usize, what: &str| -> Result<usize> {
            let i = s
                .parse::<usize>()
                .map_err(|e| crate::err!("line {}: bad {what} index {s:?}: {e}", no + 1))?;
            let i = i
                .checked_sub(base)
                .with_context(|| format!("line {}: {what} index 0 in a 1-based file", no + 1))?;
            if i >= extent {
                crate::bail!(
                    "line {}: {what} index {i} outside 0..{extent} (after {}-based adjustment)",
                    no + 1,
                    base
                );
            }
            Ok(i)
        };
        let r = idx(f[0], rows, "row")?;
        let c = idx(f[1], cols, "column")?;
        triplets.push((r, c, value));
    }
    if triplets.len() != nnz {
        crate::bail!(
            "file ends after {} entries but the header declared {nnz} (truncated file?)",
            triplets.len()
        );
    }
    Ok(auto_storage(Csr::from_triplets(rows, cols, triplets)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_coo_roundtrip() {
        let m = parse_coo("# sparse 3x4\n3 4 3\n0 0 1.5\n2 3 2.0\n1 1 0.25\n").unwrap();
        assert_eq!((m.rows(), m.cols()), (3, 4));
        assert_eq!(m.nnz(), 3);
        match &m {
            Matrix::Sparse(s) => {
                let d = s.to_dense();
                assert_eq!(d.get(0, 0), 1.5);
                assert_eq!(d.get(2, 3), 2.0);
                assert_eq!(d.get(1, 1), 0.25);
            }
            Matrix::Dense(_) => panic!("3 of 12 entries must stay sparse"),
        }
    }

    #[test]
    fn matrix_market_one_based_and_pattern() {
        let real = "%%MatrixMarket matrix coordinate real general\n% comment\n2 2 2\n1 1 3.0\n2 2 4.0\n";
        let m = parse_coo(real).unwrap();
        assert_eq!(m.nnz(), 2);
        let d = match &m {
            Matrix::Dense(d) => d.clone(),
            Matrix::Sparse(s) => s.to_dense(),
        };
        assert_eq!((d.get(0, 0), d.get(1, 1)), (3.0, 4.0));

        let pat = "%%MatrixMarket matrix coordinate pattern general\n2 3 2\n1 3\n2 1\n";
        let m = parse_coo(pat).unwrap();
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn duplicates_are_summed() {
        let m = parse_coo("2 2 2\n0 1 1.0\n0 1 2.5\n").unwrap();
        assert_eq!(m.nnz(), 1, "duplicates must merge");
        if let Matrix::Sparse(s) = &m {
            assert_eq!(s.values(), &[3.5]);
        }
    }

    #[test]
    fn dense_storage_for_dense_files() {
        let mut text = String::from("2 2 4\n");
        for r in 0..2 {
            for c in 0..2 {
                text.push_str(&format!("{r} {c} 1.0\n"));
            }
        }
        assert!(matches!(parse_coo(&text).unwrap(), Matrix::Dense(_)));
    }

    #[test]
    fn malformed_inputs_error_cleanly() {
        for (tag, text) in [
            ("empty", ""),
            ("comment only", "# nothing\n% here\n"),
            ("short header", "3 4\n"),
            ("bad header token", "3 x 2\n0 0 1\n0 1 1\n"),
            ("zero dims", "0 4 0\n"),
            ("bad value", "2 2 1\n0 0 abc\n"),
            ("negative value", "2 2 1\n0 0 -1.0\n"),
            ("non-finite value", "2 2 1\n0 0 inf\n"),
            ("row out of range", "2 2 1\n2 0 1.0\n"),
            ("col out of range", "2 2 1\n0 5 1.0\n"),
            ("truncated entries", "2 2 3\n0 0 1.0\n"),
            ("extra entries", "2 2 1\n0 0 1.0\n1 1 1.0\n"),
            ("two fields no pattern", "2 2 1\n0 0\n"),
            ("symmetric banner", "%%MatrixMarket matrix coordinate real symmetric\n2 2 1\n1 1 1\n"),
            ("array banner", "%%MatrixMarket matrix array real general\n2 2 1\n1 1 1\n"),
            ("unknown banner", "%%NotMatrixMarket\n2 2 1\n0 0 1\n"),
            ("one-based zero index", "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1\n"),
        ] {
            let r = parse_coo(text);
            assert!(r.is_err(), "{tag}: malformed input must error");
        }
    }

    #[test]
    fn load_matrix_io_error_has_context() {
        let err = load_matrix(Path::new("/definitely/not/here.mtx")).unwrap_err();
        assert!(err.to_string().contains("matrix file"), "{err}");
    }
}
