//! Shard-aware data plane: rank-local blocks of the input matrix.
//!
//! The paper's premise (Sec. 3.1, Fig. 1a) is that node `r` of an `N`-node
//! cluster holds only its row block `M_{I_r:}` and/or column block
//! `M_{:J_r}` of the input. Until this module existed, our real worker
//! processes (`dsanls worker`) regenerated the *full* matrix from the seed
//! and sliced it locally — wasting memory and CPU at every rank and
//! capping the input size at one worker's RAM. The shard data plane fixes
//! that end to end:
//!
//! * **[`NodeData`]** — what one rank actually holds: global shape, the
//!   owned index ranges, the resident blocks, and (once resolved) the
//!   exact global `‖M‖²_F` that seeds factor initialisation.
//! * **Shard-local synthesis** — [`NodeData::generate`] materialises only
//!   the rank's blocks via the windowed generators
//!   ([`crate::data::synth`]), bit-identical to slicing the full matrix
//!   (the generators replay the full random stream and keep the in-window
//!   draws).
//! * **On-disk shards** — `dsanls shard` pre-slices a dataset into a
//!   directory of per-rank block files plus a [`ShardManifest`]
//!   ([`write_shard_dir`] / [`NodeData::load`]), so multi-host deployments
//!   copy each rank only its blocks. The manifest records the exact global
//!   norm, so file-fed ranks skip the startup reduction entirely.
//! * **[`exact_fro_sq`]** — an ordered chain reduction that reproduces the
//!   full-matrix `‖M‖²_F` **bit-for-bit** from row blocks: `fro_sq`
//!   accumulates sequentially in storage order, and row blocks concatenate
//!   to exactly that order, so threading the running accumulator through
//!   the ranks (rank 0 → 1 → … → N−1) performs the identical sequence of
//!   f64 additions. This is what keeps sharded workers' factors
//!   bit-identical to the full-matrix simulator (`--verify-sim`).
//!
//! Residency contract: a rank building [`NodeData`] never allocates a
//! full-matrix-sized buffer — asserted by `tests/shard_residency.rs` with
//! a peak-tracking allocator.

use std::io::{BufReader, BufWriter, Read, Write};
use std::ops::Range;
use std::path::{Path, PathBuf};

use crate::data::datasets::Dataset;
use crate::data::partition::{uniform_partition, Partition};
use crate::error::{Context, Result};
use crate::linalg::{Csr, Mat, Matrix};
use crate::transport::wire::{push_f64_bits, take_f64_bits};
use crate::transport::Communicator;

/// Which axis of `M` a shard block spans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// A row block `M_{I_r:}` (all columns).
    Row,
    /// A column block `M_{:J_r}` (all rows).
    Col,
}

impl Axis {
    /// Stable on-disk / on-wire code.
    pub fn code(self) -> u8 {
        match self {
            Axis::Row => 0,
            Axis::Col => 1,
        }
    }

    /// Inverse of [`Axis::code`].
    pub fn from_code(c: u8) -> Result<Axis> {
        match c {
            0 => Ok(Axis::Row),
            1 => Ok(Axis::Col),
            other => crate::bail!("unknown shard axis code {other}"),
        }
    }

    /// File-name fragment (`rows` / `cols`).
    pub fn name(self) -> &'static str {
        match self {
            Axis::Row => "rows",
            Axis::Col => "cols",
        }
    }
}

/// Identifies one rank's block along one axis of a partitioned matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardSpec {
    /// Owning rank.
    pub rank: usize,
    /// Cluster data ranks (the async parameter server holds no data).
    pub nodes: usize,
    /// Partitioned axis.
    pub axis: Axis,
    /// Owned global index range along that axis.
    pub range: Range<usize>,
}

impl ShardSpec {
    /// The uniform-partition shard of `rank` along `axis` for a matrix
    /// with `total` rows/columns on that axis.
    pub fn uniform(axis: Axis, rank: usize, nodes: usize, total: usize) -> ShardSpec {
        ShardSpec { rank, nodes, axis, range: uniform_partition(total, nodes).range(rank) }
    }
}

/// Where a rank's resident data came from (surfaced per rank in
/// [`crate::coordinator::Outcome::loads`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadSource {
    /// Full matrix materialised then sliced (simulator / legacy path).
    FullMatrix,
    /// Blocks synthesised shard-locally from the seed (windowed
    /// generators).
    SynthShard,
    /// Blocks read from a `dsanls shard` directory.
    FileShard,
    /// Sketched views read from a `dsanls shard --compress` directory
    /// ([`crate::data::compress`]).
    CompressedShard,
}

impl LoadSource {
    /// Stable wire code.
    pub fn code(self) -> u64 {
        match self {
            LoadSource::FullMatrix => 0,
            LoadSource::SynthShard => 1,
            LoadSource::FileShard => 2,
            LoadSource::CompressedShard => 3,
        }
    }

    /// Inverse of [`LoadSource::code`].
    pub fn from_code(c: u64) -> Result<LoadSource> {
        match c {
            0 => Ok(LoadSource::FullMatrix),
            1 => Ok(LoadSource::SynthShard),
            2 => Ok(LoadSource::FileShard),
            3 => Ok(LoadSource::CompressedShard),
            other => crate::bail!("unknown load source code {other}"),
        }
    }

    /// Human-readable label for run summaries.
    pub fn label(self) -> &'static str {
        match self {
            LoadSource::FullMatrix => "full matrix",
            LoadSource::SynthShard => "synthetic shard",
            LoadSource::FileShard => "file shard",
            LoadSource::CompressedShard => "compressed shard",
        }
    }
}

/// Per-rank data-plane statistics: what was loaded, how big it is resident,
/// and how long loading took.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadStats {
    /// Reporting rank.
    pub rank: usize,
    /// Rows of the resident row block (0 if none held).
    pub block_rows: usize,
    /// Columns of the resident column block (0 if none held).
    pub block_cols: usize,
    /// Explicitly stored values across resident blocks.
    pub nnz: usize,
    /// Approximate resident bytes across blocks.
    pub bytes: usize,
    /// Wall seconds spent building/loading the blocks.
    pub load_secs: f64,
    /// Provenance of the blocks.
    pub source: LoadSource,
}

/// Approximate resident bytes of a matrix (values + sparse index arrays).
pub fn matrix_resident_bytes(m: &Matrix) -> usize {
    match m {
        Matrix::Dense(d) => d.data().len() * 4,
        Matrix::Sparse(s) => s.nnz() * (4 + 8) + (s.rows() + 1) * 8,
    }
}

/// Bitwise matrix equality (dense: dims + data bits; sparse: full CSR
/// structure) — the assertion primitive of the shard bit-identity tests.
pub fn matrix_bits_eq(a: &Matrix, b: &Matrix) -> bool {
    match (a, b) {
        (Matrix::Dense(x), Matrix::Sparse(y)) => &y.to_dense() == x,
        (Matrix::Sparse(x), Matrix::Dense(y)) => &x.to_dense() == y,
        (Matrix::Dense(x), Matrix::Dense(y)) => x == y,
        (Matrix::Sparse(x), Matrix::Sparse(y)) => x == y,
    }
}

// ---------------------------------------------------------------------------
// NodeData: what one rank holds
// ---------------------------------------------------------------------------

/// One rank's view of the partitioned input matrix.
///
/// Constructed three ways — [`NodeData::from_full`] (slice a materialised
/// matrix; simulator and tests), [`NodeData::generate`] (shard-local
/// synthesis), [`NodeData::load`] (shard directory) — and consumed, via
/// [`NodeInput::Shard`], by the per-rank node runners in [`crate::algos`]
/// / [`crate::secure`].
#[derive(Debug, Clone)]
pub struct NodeData {
    /// Global matrix rows.
    pub rows: usize,
    /// Global matrix columns.
    pub cols: usize,
    /// Global row indices of `m_rows` (empty range if no row block).
    pub row_range: Range<usize>,
    /// Global column indices of `m_cols` (empty range if no column block).
    pub col_range: Range<usize>,
    /// Resident row block `M_{I_r:}` (`|I_r| × cols`).
    pub m_rows: Option<Matrix>,
    /// Resident column block `M_{:J_r}` (`rows × |J_r|`).
    pub m_cols: Option<Matrix>,
    /// Exact global `‖M‖²_F`, once known (manifest or [`exact_fro_sq`]).
    pub fro_sq: Option<f64>,
}

impl NodeData {
    /// Slice a rank's view out of a materialised matrix (the legacy /
    /// simulator path; also the oracle the bit-identity tests compare
    /// against).
    pub fn from_full(m: &Matrix, row_range: Range<usize>, col_range: Range<usize>) -> NodeData {
        NodeData {
            rows: m.rows(),
            cols: m.cols(),
            m_rows: Some(m.row_block(row_range.clone())),
            m_cols: Some(m.col_block(col_range.clone())),
            row_range,
            col_range,
            fro_sq: Some(m.fro_sq()),
        }
    }

    /// Synthesise a rank's blocks shard-locally (no full-matrix buffer is
    /// ever allocated). Pass `None` for a block the rank does not need.
    /// When both blocks are requested they are filled in a **single pass**
    /// over the generator stream ([`Dataset::generate_windows`]) — one
    /// replay instead of one per block, halving shard-local generation CPU
    /// — and stay bit-identical to slicing the full matrix. `fro_sq`
    /// starts unresolved — run [`exact_fro_sq`] before algorithms that
    /// initialise factors.
    pub fn generate(
        dataset: Dataset,
        seed: u64,
        scale: f64,
        row_range: Option<Range<usize>>,
        col_range: Option<Range<usize>>,
    ) -> NodeData {
        let (rows, cols) = dataset.scaled_shape(scale);
        let mut windows = Vec::with_capacity(2);
        if let Some(r) = &row_range {
            windows.push(crate::data::synth::GenWindow { rows: r.clone(), cols: 0..cols });
        }
        if let Some(c) = &col_range {
            windows.push(crate::data::synth::GenWindow { rows: 0..rows, cols: c.clone() });
        }
        let mut blocks = if windows.is_empty() {
            Vec::new()
        } else {
            dataset.generate_windows(seed, scale, &windows)
        };
        // generate_windows returns blocks in window order: row first (when
        // requested), then column — pop back-to-front
        let m_cols = col_range.as_ref().map(|_| blocks.pop().expect("column block generated"));
        let m_rows = row_range.as_ref().map(|_| blocks.pop().expect("row block generated"));
        NodeData {
            rows,
            cols,
            row_range: row_range.unwrap_or(0..0),
            col_range: col_range.unwrap_or(0..0),
            m_rows,
            m_cols,
            fro_sq: None,
        }
    }

    /// A metadata-only view: global shape plus the exact global `‖M‖²`, no
    /// resident blocks — what the asynchronous parameter server (which
    /// holds no data) runs on.
    pub fn metadata(rows: usize, cols: usize, fro_sq: Option<f64>) -> NodeData {
        NodeData {
            rows,
            cols,
            row_range: 0..0,
            col_range: 0..0,
            m_rows: None,
            m_cols: None,
            fro_sq,
        }
    }

    /// Load a rank's blocks from a `dsanls shard` directory. Returns the
    /// manifest alongside so callers can validate it against their config.
    pub fn load(
        dir: &Path,
        rank: usize,
        need_rows: bool,
        need_cols: bool,
    ) -> Result<(NodeData, ShardManifest)> {
        let manifest = read_manifest(dir)?;
        if rank >= manifest.nodes {
            crate::bail!("rank {rank} outside shard set of {} nodes", manifest.nodes);
        }
        let mut data = NodeData {
            rows: manifest.rows,
            cols: manifest.cols,
            row_range: 0..0,
            col_range: 0..0,
            m_rows: None,
            m_cols: None,
            fro_sq: Some(manifest.fro_sq),
        };
        if need_rows {
            let (spec, block) = read_block(dir, rank, Axis::Row)?;
            validate_block(&manifest, &spec, &block, Axis::Row)?;
            if spec.range != manifest.row_partition().range(rank) {
                crate::bail!(
                    "rank {rank} row block spans {:?} but the manifest partitions it at {:?} \
                     (mixed shard sets?)",
                    spec.range,
                    manifest.row_partition().range(rank)
                );
            }
            data.row_range = spec.range;
            data.m_rows = Some(block);
        }
        if need_cols {
            let (spec, block) = read_block(dir, rank, Axis::Col)?;
            validate_block(&manifest, &spec, &block, Axis::Col)?;
            if spec.range != manifest.col_partition().range(rank) {
                crate::bail!(
                    "rank {rank} col block spans {:?} but the manifest partitions it at {:?} \
                     (mixed shard sets?)",
                    spec.range,
                    manifest.col_partition().range(rank)
                );
            }
            data.col_range = spec.range;
            data.m_cols = Some(block);
        }
        Ok((data, manifest))
    }

    /// The resident row block, or a diagnostic panic if this rank holds
    /// none (entry points state their block requirements).
    pub fn require_rows(&self) -> &Matrix {
        self.m_rows.as_ref().expect("this algorithm requires the rank's row block")
    }

    /// The resident column block (see [`NodeData::require_rows`]).
    pub fn require_cols(&self) -> &Matrix {
        self.m_cols.as_ref().expect("this algorithm requires the rank's column block")
    }

    /// The resolved exact global `‖M‖²_F`; panics if unresolved (callers
    /// must run [`exact_fro_sq`] or load a manifest first).
    pub fn fro_sq(&self) -> f64 {
        self.fro_sq.expect("global ‖M‖² unresolved — run exact_fro_sq first")
    }

    /// Drop the row block (e.g. after the startup norm reduction when the
    /// algorithm only consumes the column block).
    pub fn drop_rows(&mut self) {
        self.m_rows = None;
        self.row_range = 0..0;
    }

    /// Approximate resident bytes across the held blocks.
    pub fn resident_bytes(&self) -> usize {
        self.m_rows.as_ref().map_or(0, matrix_resident_bytes)
            + self.m_cols.as_ref().map_or(0, matrix_resident_bytes)
    }

    /// Explicitly stored values across the held blocks.
    pub fn nnz(&self) -> usize {
        self.m_rows.as_ref().map_or(0, Matrix::nnz) + self.m_cols.as_ref().map_or(0, Matrix::nnz)
    }

    /// Summarise into per-rank [`LoadStats`].
    pub fn load_stats(&self, rank: usize, load_secs: f64, source: LoadSource) -> LoadStats {
        LoadStats {
            rank,
            block_rows: self.m_rows.as_ref().map_or(0, Matrix::rows),
            block_cols: self.m_cols.as_ref().map_or(0, Matrix::cols),
            nnz: self.nnz(),
            bytes: self.resident_bytes(),
            load_secs,
            source,
        }
    }
}

/// The input a per-rank algorithm entry point runs on: either the full
/// matrix (simulator, tests — every rank slices its own blocks) or a
/// pre-sharded [`NodeData`] view (real workers). This is the single
/// resolved view the per-algorithm node runners
/// ([`crate::algos::dsanls::dsanls_rank`], [`crate::secure::syn::syn_rank`],
/// …) take — there are no separate full/sharded entry points.
#[derive(Clone, Copy)]
pub enum NodeInput<'a> {
    /// The rank can see the whole matrix and slices its blocks itself.
    Full(&'a Matrix),
    /// The rank holds only its blocks.
    Shard(&'a NodeData),
    /// The rank holds only fixed sketched views of its blocks
    /// ([`crate::data::compress::CompressedBlock`]); no raw data exists
    /// anywhere in the process.
    Compressed(&'a crate::data::compress::CompressedBlock),
}

impl<'a> NodeInput<'a> {
    /// Global `(rows, cols)`.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            NodeInput::Full(m) => (m.rows(), m.cols()),
            NodeInput::Shard(d) => (d.rows, d.cols),
            NodeInput::Compressed(b) => (b.rows, b.cols),
        }
    }

    /// Exact global `‖M‖²_F` — for compressed input, the sketched-domain
    /// norm `‖M·S_c‖²_F` (the constant every trace/init quantity is
    /// defined against when no raw data exists; recorded in the manifest).
    pub fn fro_sq(&self) -> f64 {
        match self {
            NodeInput::Full(m) => m.fro_sq(),
            NodeInput::Shard(d) => d.fro_sq(),
            NodeInput::Compressed(b) => b.sketched_fro_sq,
        }
    }

    /// The compressed view, when this input is one — runners branch on
    /// this once at entry and never touch the raw-block accessors.
    pub fn compressed(&self) -> Option<&'a crate::data::compress::CompressedBlock> {
        match self {
            NodeInput::Compressed(b) => Some(b),
            _ => None,
        }
    }

    /// The rank's row block `M_{I_r:}` for the given partition range:
    /// sliced out of the full matrix, or borrowed from the shard view
    /// (whose range must match the rank's partition — the shard contract).
    pub fn row_block(&self, expect: Range<usize>) -> std::borrow::Cow<'_, Matrix> {
        match self {
            NodeInput::Full(m) => std::borrow::Cow::Owned(m.row_block(expect)),
            NodeInput::Shard(d) => {
                assert_eq!(d.row_range, expect, "shard row range != rank's partition");
                std::borrow::Cow::Borrowed(d.require_rows())
            }
            NodeInput::Compressed(_) => {
                panic!("compressed input holds only sketched views, no raw row block")
            }
        }
    }

    /// The rank's column block `M_{:J_r}` for the given partition range:
    /// sliced out of the full matrix, or borrowed from the shard view
    /// (whose range must match the rank's partition — the shard contract).
    pub fn col_block(&self, expect: Range<usize>) -> std::borrow::Cow<'_, Matrix> {
        match self {
            NodeInput::Full(m) => std::borrow::Cow::Owned(m.col_block(expect)),
            NodeInput::Shard(d) => {
                assert_eq!(d.col_range, expect, "shard col range != rank's partition");
                std::borrow::Cow::Borrowed(d.require_cols())
            }
            NodeInput::Compressed(_) => {
                panic!("compressed input holds only sketched views, no raw col block")
            }
        }
    }

    /// The rank's transposed column block `(M_{:J_r})ᵀ` for the given
    /// partition range (always owned — the transpose materialises).
    pub fn col_block_t(&self, expect: Range<usize>) -> Matrix {
        match self {
            NodeInput::Full(m) => m.col_block(expect).transpose(),
            NodeInput::Shard(d) => {
                assert_eq!(d.col_range, expect, "shard col range != rank's partition");
                d.require_cols().transpose()
            }
            NodeInput::Compressed(_) => {
                panic!("compressed input holds only sketched views, no raw col block")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Exact global norm from row blocks (ordered chain reduction)
// ---------------------------------------------------------------------------

/// Continue the sequential `‖·‖²_F` accumulation from `acc` over `m`'s
/// stored values in storage order — the resumable form of
/// [`Matrix::fro_sq`] (which is `fro_sq_resume(m, 0.0)`).
pub(crate) fn fro_sq_resume(m: &Matrix, acc: f64) -> f64 {
    match m {
        Matrix::Dense(d) => d.data().iter().fold(acc, |a, &v| a + (v as f64) * (v as f64)),
        Matrix::Sparse(s) => s.values().iter().fold(acc, |a, &v| a + (v as f64) * (v as f64)),
    }
}

/// Compute the **exact** global `‖M‖²_F` from distributed row blocks.
///
/// Ranks `0..contributors` each hold the row block of a rank-ordered row
/// partition (`my_rows = Some(block)`); any further ranks (e.g. the async
/// parameter server) participate with `None`. Round `r` of the chain:
/// rank `r` folds its block's values into the running accumulator —
/// *starting from the value rank `r−1` produced* — and broadcasts the new
/// accumulator to everyone via the collective exchange.
///
/// Because dense data and CSR values are stored row-major, the
/// concatenation of rank-ordered row blocks **is** the full matrix's
/// storage order, and resuming a sequential fold is associative-free: the
/// result is bit-identical to `m.fro_sq()` on the materialised matrix.
/// Cost: `contributors` tiny barriers at startup, once per run.
pub fn exact_fro_sq<C: Communicator>(
    comm: &mut C,
    contributors: usize,
    my_rows: Option<&Matrix>,
) -> Result<f64> {
    assert!(contributors >= 1, "exact_fro_sq needs at least one contributor");
    assert!(contributors <= comm.nodes(), "more contributors than ranks");
    let mut acc = 0.0f64;
    for r in 0..contributors {
        let payload = if comm.rank() == r {
            let block = my_rows
                .with_context(|| format!("rank {r} contributes to ‖M‖² but holds no row block"))?;
            let mut p = Vec::with_capacity(2);
            push_f64_bits(&mut p, fro_sq_resume(block, acc));
            p
        } else {
            Vec::new()
        };
        let gathered = comm
            .exchange(0.0, &payload)
            .with_context(|| format!("‖M‖² chain round {r}"))?;
        let mut pos = 0;
        acc = take_f64_bits(&gathered.parts[r], &mut pos)
            .with_context(|| format!("rank {r} sent a malformed ‖M‖² accumulator"))?;
    }
    Ok(acc)
}

// ---------------------------------------------------------------------------
// On-disk shard format
// ---------------------------------------------------------------------------

/// Shard directory metadata (`manifest.bin`): what was sharded, for how
/// many ranks, the exact global norm, and the partition cut points each
/// axis was sliced at (uniform by default; nnz-balanced with `dsanls
/// shard --balance nnz`).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardManifest {
    /// Data ranks the directory was sharded for.
    pub nodes: usize,
    /// Global matrix rows.
    pub rows: usize,
    /// Global matrix columns.
    pub cols: usize,
    /// Exact global `‖M‖²_F` of the sharded matrix.
    pub fro_sq: f64,
    /// Generator seed the matrix came from.
    pub seed: u64,
    /// Generator scale.
    pub scale: f64,
    /// Dense (`true`) or CSR (`false`) storage.
    pub dense: bool,
    /// Dataset name (upper-case, e.g. `FACE`).
    pub dataset: String,
    /// Row-axis cut points (`nodes + 1` values, `[0, …, rows]`).
    pub row_bounds: Vec<usize>,
    /// Column-axis cut points (`nodes + 1` values, `[0, …, cols]`).
    pub col_bounds: Vec<usize>,
}

impl ShardManifest {
    /// A manifest for uniform partitions along both axes (the default).
    #[allow(clippy::too_many_arguments)]
    pub fn uniform(
        nodes: usize,
        rows: usize,
        cols: usize,
        fro_sq: f64,
        seed: u64,
        scale: f64,
        dense: bool,
        dataset: String,
    ) -> ShardManifest {
        ShardManifest {
            nodes,
            rows,
            cols,
            fro_sq,
            seed,
            scale,
            dense,
            dataset,
            row_bounds: uniform_partition(rows, nodes).bounds(),
            col_bounds: uniform_partition(cols, nodes).bounds(),
        }
    }

    /// The row partition the directory was sliced with.
    pub fn row_partition(&self) -> Partition {
        Partition::from_bounds(&self.row_bounds).expect("manifest bounds validated on read")
    }

    /// The column partition the directory was sliced with.
    pub fn col_partition(&self) -> Partition {
        Partition::from_bounds(&self.col_bounds).expect("manifest bounds validated on read")
    }

    /// Is either axis partitioned non-uniformly (`--balance nnz`)?
    pub fn is_balanced(&self) -> bool {
        self.row_bounds != uniform_partition(self.rows, self.nodes).bounds()
            || self.col_bounds != uniform_partition(self.cols, self.nodes).bounds()
    }

    /// The single shared gate for balanced directories: the non-secure
    /// algorithms assume uniform partitions, so they must refuse an
    /// nnz-balanced shard set — with the same typed error whether the run
    /// comes through the in-process [`crate::nmf::job::Job`] or a
    /// `dsanls worker` (one predicate, one message).
    pub fn require_uniform_for(&self, dir: &Path, secure: bool) -> Result<()> {
        if self.is_balanced() && !secure {
            crate::bail!(
                "shard directory {} carries nnz-balanced partitions, which only the \
                 secure protocols consume — re-shard without `--balance nnz`",
                dir.display()
            );
        }
        Ok(())
    }
}

/// Per-column stored-value counts — the weights `dsanls shard --balance
/// nnz` feeds [`crate::data::partition::weight_balanced_partition`]. A
/// dense matrix stores every entry, so its columns weigh equally (balance
/// degrades to uniform, as it should).
pub fn col_nnz_counts(m: &Matrix) -> Vec<usize> {
    match m {
        Matrix::Dense(d) => vec![d.rows(); d.cols()],
        Matrix::Sparse(s) => {
            let mut counts = vec![0usize; s.cols()];
            for &c in s.indices() {
                counts[c] += 1;
            }
            counts
        }
    }
}

/// Manifest dataset-name prefix marking shards sliced from an external
/// matrix file (`dsanls shard --input`) rather than a synthetic generator.
pub const FILE_DATASET_PREFIX: &str = "FILE:";

/// The manifest dataset name for shards of the external file at `path`.
pub fn file_dataset_name(path: &Path) -> String {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("matrix");
    format!("{FILE_DATASET_PREFIX}{stem}")
}

/// Whether a manifest dataset name marks file-ingested (non-regenerable)
/// shards.
pub fn is_file_dataset(name: &str) -> bool {
    name.starts_with(FILE_DATASET_PREFIX)
}

/// On-disk format version; bump on any layout change (readers reject
/// mismatches with a "regenerate your shards" diagnostic). Version 2
/// added the per-axis partition cut points to the manifest (`--balance
/// nnz` shard sets).
pub const SHARD_FORMAT_VERSION: u32 = 2;

/// Shared by raw (v2) and compressed (v3, [`crate::data::compress`])
/// manifests — the version field after the magic disambiguates.
pub(crate) const MANIFEST_MAGIC: &[u8; 8] = b"DSSHMAN1";
const BLOCK_MAGIC: &[u8; 8] = b"DSSHBLK1";

/// Path of the manifest inside a shard directory.
pub fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest.bin")
}

/// Path of one rank's block file along `axis`.
pub fn block_path(dir: &Path, rank: usize, axis: Axis) -> PathBuf {
    dir.join(format!("rank-{rank}.{}.blk", axis.name()))
}

/// Scalar/bulk encodings come from the shared [`crate::binio`] module
/// (bulk reads are one `read_exact` per array — block files exist for
/// RCV1-scale inputs); `IO` pins the "shard file" error wording.
const IO: crate::binio::BinFormat = crate::binio::SHARD;

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    IO.write_u64(w, v)
}

fn write_u32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    IO.write_u32(w, v)
}

fn write_f32s<W: Write>(w: &mut W, vs: &[f32]) -> Result<()> {
    IO.write_f32s(w, vs)
}

fn write_u64s<W: Write>(w: &mut W, vs: &[usize]) -> Result<()> {
    IO.write_u64s(w, vs)
}

fn read_exact_ctx<R: Read>(r: &mut R, buf: &mut [u8], what: &str) -> Result<()> {
    IO.read_exact(r, buf, what)
}

fn read_u64<R: Read>(r: &mut R, what: &str) -> Result<u64> {
    IO.read_u64(r, what)
}

fn read_u32<R: Read>(r: &mut R, what: &str) -> Result<u32> {
    IO.read_u32(r, what)
}

fn read_f32s<R: Read>(r: &mut R, n: usize, what: &str) -> Result<Vec<f32>> {
    IO.read_f32s(r, n, what)
}

fn read_u64s<R: Read>(r: &mut R, n: usize, what: &str) -> Result<Vec<usize>> {
    IO.read_u64s(r, n, what)
}

fn check_magic<R: Read>(r: &mut R, expect: &[u8; 8], what: &str) -> Result<()> {
    let mut got = [0u8; 8];
    read_exact_ctx(r, &mut got, "magic")?;
    if &got != expect {
        crate::bail!("{what}: bad magic {got:02x?} — not a dsanls shard file");
    }
    let version = read_u32(r, "format version")?;
    if version == crate::data::compress::COMPRESSED_FORMAT_VERSION {
        crate::bail!(
            "{what}: format version {version} marks a *compressed* shard set \
             (`dsanls shard --compress`) — this code path reads raw shards \
             (launch/worker autodetect; in-process jobs use DataSource::Compressed)"
        );
    }
    if version != SHARD_FORMAT_VERSION {
        crate::bail!(
            "{what}: shard format version {version}, this binary reads \
             {SHARD_FORMAT_VERSION} — regenerate with `dsanls shard`"
        );
    }
    Ok(())
}

/// Write a complete shard directory: `manifest.bin` plus one row-axis and
/// one column-axis block file per rank, sliced from the materialised `m`
/// along the partitions the manifest records (uniform by default,
/// nnz-balanced for `--balance nnz`). (Shard preparation is the one place
/// the full matrix may exist; workers then touch only their blocks.)
/// Returns the total bytes written.
pub fn write_shard_dir(dir: &Path, m: &Matrix, manifest: &ShardManifest) -> Result<u64> {
    assert_eq!((manifest.rows, manifest.cols), (m.rows(), m.cols()), "manifest/matrix shape");
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating shard directory {}", dir.display()))?;
    let mut total = write_manifest(dir, manifest)?;
    let row_part = manifest.row_partition();
    let col_part = manifest.col_partition();
    for rank in 0..manifest.nodes {
        for axis in [Axis::Row, Axis::Col] {
            let range = match axis {
                Axis::Row => row_part.range(rank),
                Axis::Col => col_part.range(rank),
            };
            let spec = ShardSpec { rank, nodes: manifest.nodes, axis, range };
            let block = match axis {
                Axis::Row => m.row_block(spec.range.clone()),
                Axis::Col => m.col_block(spec.range.clone()),
            };
            total += write_block(dir, &spec, &block)?;
        }
    }
    Ok(total)
}

pub(crate) fn write_manifest(dir: &Path, manifest: &ShardManifest) -> Result<u64> {
    let path = manifest_path(dir);
    let file = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(MANIFEST_MAGIC).context("writing manifest magic")?;
    write_u32(&mut w, SHARD_FORMAT_VERSION)?;
    write_manifest_body(&mut w, IO, manifest)?;
    w.flush().context("flushing manifest")?;
    Ok(std::fs::metadata(&path).map(|md| md.len()).unwrap_or(0))
}

/// Write the manifest fields that follow the magic + version header — the
/// single source of the v2 field order, shared with the compressed (v3)
/// manifest writer in [`crate::data::compress`], which appends its
/// extension fields after this body.
pub(crate) fn write_manifest_body<W: Write>(
    w: &mut W,
    io: crate::binio::BinFormat,
    manifest: &ShardManifest,
) -> Result<()> {
    io.write_u64(w, manifest.nodes as u64)?;
    io.write_u64(w, manifest.rows as u64)?;
    io.write_u64(w, manifest.cols as u64)?;
    io.write_f64(w, manifest.fro_sq)?;
    io.write_u64(w, manifest.seed)?;
    io.write_f64(w, manifest.scale)?;
    w.write_all(&[manifest.dense as u8]).context("writing manifest storage kind")?;
    let name = manifest.dataset.as_bytes();
    io.write_u32(w, name.len() as u32)?;
    w.write_all(name).context("writing manifest dataset name")?;
    debug_assert_eq!(manifest.row_bounds.len(), manifest.nodes + 1, "row bounds shape");
    debug_assert_eq!(manifest.col_bounds.len(), manifest.nodes + 1, "col bounds shape");
    io.write_u64s(w, &manifest.row_bounds)?;
    io.write_u64s(w, &manifest.col_bounds)?;
    Ok(())
}

/// Read and validate a shard directory's manifest. Every parse error —
/// including truncation/corruption deep inside the fields — carries the
/// offending file path.
pub fn read_manifest(dir: &Path) -> Result<ShardManifest> {
    let path = manifest_path(dir);
    read_manifest_file(&path)
        .with_context(|| format!("reading shard manifest {}", path.display()))
}

fn read_manifest_file(path: &Path) -> Result<ShardManifest> {
    let file = std::fs::File::open(path).context("opening file")?;
    let mut r = BufReader::new(file);
    check_magic(&mut r, MANIFEST_MAGIC, "manifest")?;
    read_manifest_body(&mut r, IO)
}

/// Read the manifest fields that follow the magic + version header (the
/// inverse of [`write_manifest_body`]; shared with the v3 reader).
pub(crate) fn read_manifest_body<R: Read>(
    r: &mut R,
    io: crate::binio::BinFormat,
) -> Result<ShardManifest> {
    let nodes = io.read_u64(r, "nodes")? as usize;
    let rows = io.read_u64(r, "rows")? as usize;
    let cols = io.read_u64(r, "cols")? as usize;
    let fro_sq = io.read_f64(r, "fro_sq")?;
    let seed = io.read_u64(r, "seed")?;
    let scale = io.read_f64(r, "scale")?;
    let mut dense = [0u8; 1];
    io.read_exact(r, &mut dense, "storage kind")?;
    let name_len = io.read_u32(r, "dataset name length")? as usize;
    if name_len > 256 {
        crate::bail!("manifest dataset name length {name_len} is implausible (corrupt file?)");
    }
    let mut name = vec![0u8; name_len];
    io.read_exact(r, &mut name, "dataset name")?;
    let dataset = String::from_utf8(name).map_err(|_| crate::err!("manifest name not UTF-8"))?;
    if nodes == 0 || rows == 0 || cols == 0 {
        crate::bail!("manifest with zero nodes/rows/cols (corrupt file?)");
    }
    if nodes > 1 << 20 {
        crate::bail!("manifest claims {nodes} nodes (corrupt file?)");
    }
    let row_bounds = io.read_u64s(r, nodes + 1, "row partition bounds")?;
    let col_bounds = io.read_u64s(r, nodes + 1, "col partition bounds")?;
    for (bounds, extent, what) in [(&row_bounds, rows, "row"), (&col_bounds, cols, "col")] {
        let p = Partition::from_bounds(bounds)
            .with_context(|| format!("manifest {what} partition bounds"))?;
        if p.total != extent || !p.validate() {
            crate::bail!("manifest {what} partition does not cover 0..{extent} (corrupt file?)");
        }
    }
    Ok(ShardManifest {
        nodes,
        rows,
        cols,
        fro_sq,
        seed,
        scale,
        dense: dense[0] != 0,
        dataset,
        row_bounds,
        col_bounds,
    })
}

pub(crate) fn write_block(dir: &Path, spec: &ShardSpec, block: &Matrix) -> Result<u64> {
    let path = block_path(dir, spec.rank, spec.axis);
    let file = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(BLOCK_MAGIC).context("writing block magic")?;
    write_u32(&mut w, SHARD_FORMAT_VERSION)?;
    w.write_all(&[spec.axis.code()]).context("writing block axis")?;
    write_u64(&mut w, spec.rank as u64)?;
    write_u64(&mut w, spec.nodes as u64)?;
    write_u64(&mut w, spec.range.start as u64)?;
    write_u64(&mut w, spec.range.end as u64)?;
    match block {
        Matrix::Dense(d) => {
            w.write_all(&[0u8]).context("writing block kind")?;
            write_u64(&mut w, d.rows() as u64)?;
            write_u64(&mut w, d.cols() as u64)?;
            write_f32s(&mut w, d.data())?;
        }
        Matrix::Sparse(s) => {
            w.write_all(&[1u8]).context("writing block kind")?;
            write_u64(&mut w, s.rows() as u64)?;
            write_u64(&mut w, s.cols() as u64)?;
            write_u64(&mut w, s.nnz() as u64)?;
            write_u64s(&mut w, s.indptr())?;
            write_u64s(&mut w, s.indices())?;
            write_f32s(&mut w, s.values())?;
        }
    }
    w.flush().context("flushing block file")?;
    Ok(std::fs::metadata(&path).map(|md| md.len()).unwrap_or(0))
}

/// Read one rank's block along `axis` from a shard directory, validating
/// magic, format version, and that the file is the requested shard. Every
/// parse error carries the offending file path.
pub fn read_block(dir: &Path, rank: usize, axis: Axis) -> Result<(ShardSpec, Matrix)> {
    let path = block_path(dir, rank, axis);
    read_block_file(&path, rank, axis)
        .with_context(|| format!("reading shard block {}", path.display()))
}

fn read_block_file(path: &Path, rank: usize, axis: Axis) -> Result<(ShardSpec, Matrix)> {
    let file = std::fs::File::open(path).context("opening file")?;
    let mut r = BufReader::new(file);
    check_magic(&mut r, BLOCK_MAGIC, "block")?;
    let mut axis_b = [0u8; 1];
    read_exact_ctx(&mut r, &mut axis_b, "axis")?;
    let file_axis = Axis::from_code(axis_b[0])?;
    let file_rank = read_u64(&mut r, "rank")? as usize;
    let nodes = read_u64(&mut r, "nodes")? as usize;
    let start = read_u64(&mut r, "range start")? as usize;
    let end = read_u64(&mut r, "range end")? as usize;
    if file_axis != axis || file_rank != rank {
        crate::bail!(
            "block file says rank {file_rank}/{file_axis:?}, expected rank {rank}/{axis:?}"
        );
    }
    if end < start {
        crate::bail!("block range {start}..{end} is inverted (corrupt file?)");
    }
    let mut kind = [0u8; 1];
    read_exact_ctx(&mut r, &mut kind, "storage kind")?;
    let rows = read_u64(&mut r, "block rows")? as usize;
    let cols = read_u64(&mut r, "block cols")? as usize;
    // a corrupt length field must error, not attempt a huge allocation
    let sane = |n: usize, what: &str| -> Result<usize> {
        const MAX_ELEMS: usize = 1 << 31; // 8 GiB of f32s — beyond any shard we write
        if n > MAX_ELEMS {
            crate::bail!("block claims {n} {what} (corrupt length field?)");
        }
        Ok(n)
    };
    let matrix = match kind[0] {
        0 => {
            let n = sane(rows.saturating_mul(cols), "dense values")?;
            let data = read_f32s(&mut r, n, "dense payload")?;
            Matrix::Dense(Mat::from_vec(rows, cols, data))
        }
        1 => {
            let nnz = sane(read_u64(&mut r, "nnz")? as usize, "nonzeros")?;
            let indptr = read_u64s(&mut r, sane(rows, "rows")? + 1, "indptr")?;
            let indices = read_u64s(&mut r, nnz, "indices")?;
            let values = read_f32s(&mut r, nnz, "values")?;
            Matrix::Sparse(Csr::from_raw_parts(rows, cols, indptr, indices, values)?)
        }
        other => crate::bail!("unknown block storage kind {other}"),
    };
    let spec = ShardSpec { rank, nodes, axis, range: start..end };
    Ok((spec, matrix))
}

fn validate_block(
    manifest: &ShardManifest,
    spec: &ShardSpec,
    block: &Matrix,
    axis: Axis,
) -> Result<()> {
    if spec.nodes != manifest.nodes {
        crate::bail!("block sharded for {} nodes, manifest says {}", spec.nodes, manifest.nodes);
    }
    let (expect_rows, expect_cols) = match axis {
        Axis::Row => (spec.range.len(), manifest.cols),
        Axis::Col => (manifest.rows, spec.range.len()),
    };
    if (block.rows(), block.cols()) != (expect_rows, expect_cols) {
        crate::bail!(
            "block shape {}x{} does not match its header ({expect_rows}x{expect_cols})",
            block.rows(),
            block.cols()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{run_cluster, CommModel};

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dsanls_shard_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn manifest_for(m: &Matrix, nodes: usize, dataset: &str) -> ShardManifest {
        ShardManifest::uniform(
            nodes,
            m.rows(),
            m.cols(),
            m.fro_sq(),
            7,
            0.02,
            matches!(m, Matrix::Dense(_)),
            dataset.into(),
        )
    }

    #[test]
    fn synth_shards_equal_full_slices_for_all_datasets() {
        for d in crate::data::ALL_DATASETS {
            let full = d.generate_scaled(7, 0.02);
            for nodes in [1usize, 2, 3] {
                for rank in 0..nodes {
                    let rr = ShardSpec::uniform(Axis::Row, rank, nodes, full.rows()).range;
                    let cr = ShardSpec::uniform(Axis::Col, rank, nodes, full.cols()).range;
                    let shard =
                        NodeData::generate(d, 7, 0.02, Some(rr.clone()), Some(cr.clone()));
                    let oracle = NodeData::from_full(&full, rr, cr);
                    assert!(
                        matrix_bits_eq(oracle.require_rows(), shard.require_rows()),
                        "{:?} rank {rank}/{nodes}: row block mismatch",
                        d
                    );
                    assert!(
                        matrix_bits_eq(oracle.require_cols(), shard.require_cols()),
                        "{:?} rank {rank}/{nodes}: col block mismatch",
                        d
                    );
                }
            }
        }
    }

    #[test]
    fn chain_fro_sq_is_bit_exact() {
        for d in [crate::data::Dataset::Face, crate::data::Dataset::Mnist] {
            let full = d.generate_scaled(9, 0.02);
            let expect = full.fro_sq();
            for nodes in [1usize, 2, 4] {
                let got = run_cluster(nodes, CommModel::default(), |ctx| {
                    let rr =
                        ShardSpec::uniform(Axis::Row, ctx.rank, nodes, full.rows()).range;
                    let block = full.row_block(rr);
                    exact_fro_sq(ctx.comm_mut(), nodes, Some(&block)).unwrap()
                });
                for (rank, g) in got.iter().enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        expect.to_bits(),
                        "{:?} nodes={nodes} rank={rank}: {g} vs {expect}",
                        d
                    );
                }
            }
        }
    }

    #[test]
    fn shard_dir_roundtrip_dense_and_sparse() {
        for d in [crate::data::Dataset::Face, crate::data::Dataset::Mnist] {
            let full = d.generate_scaled(7, 0.02);
            let dir = tmpdir(&format!("rt_{:?}", d));
            let manifest = manifest_for(&full, 3, "X");
            write_shard_dir(&dir, &full, &manifest).unwrap();
            let back = read_manifest(&dir).unwrap();
            assert_eq!(back, manifest);
            for rank in 0..3 {
                let (data, _) = NodeData::load(&dir, rank, true, true).unwrap();
                let rr = ShardSpec::uniform(Axis::Row, rank, 3, full.rows()).range;
                let cr = ShardSpec::uniform(Axis::Col, rank, 3, full.cols()).range;
                let oracle = NodeData::from_full(&full, rr.clone(), cr.clone());
                assert_eq!(data.row_range, rr);
                assert_eq!(data.col_range, cr);
                assert!(matrix_bits_eq(oracle.require_rows(), data.require_rows()));
                assert!(matrix_bits_eq(oracle.require_cols(), data.require_cols()));
                assert_eq!(data.fro_sq().to_bits(), full.fro_sq().to_bits());
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn truncated_and_corrupt_files_error_cleanly() {
        let full = crate::data::Dataset::Face.generate_scaled(7, 0.02);
        let dir = tmpdir("trunc");
        write_shard_dir(&dir, &full, &manifest_for(&full, 2, "FACE")).unwrap();

        // truncate the manifest at several prefixes: all must error (never
        // panic) and every error must name the offending file
        let mpath = manifest_path(&dir);
        let bytes = std::fs::read(&mpath).unwrap();
        for cut in [0usize, 4, 8, 11, 20, bytes.len() - 1] {
            std::fs::write(&mpath, &bytes[..cut]).unwrap();
            let err = read_manifest(&dir).expect_err(&format!("manifest cut at {cut}"));
            assert!(
                err.to_string().contains(mpath.to_str().unwrap()),
                "manifest error at cut {cut} lacks the file path: {err}"
            );
        }
        std::fs::write(&mpath, &bytes).unwrap();

        // truncated block header and payload: error, and name the file
        let bpath = block_path(&dir, 0, Axis::Row);
        let bbytes = std::fs::read(&bpath).unwrap();
        for cut in [0usize, 7, 12, 13, 40, bbytes.len() - 1] {
            std::fs::write(&bpath, &bbytes[..cut]).unwrap();
            let err = read_block(&dir, 0, Axis::Row).expect_err(&format!("block cut at {cut}"));
            assert!(
                err.to_string().contains(bpath.to_str().unwrap()),
                "block error at cut {cut} lacks the file path: {err}"
            );
        }

        // wrong format version
        let mut vbytes = bbytes.clone();
        vbytes[8] = vbytes[8].wrapping_add(1);
        std::fs::write(&bpath, &vbytes).unwrap();
        let err = read_block(&dir, 0, Axis::Row).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        assert!(err.to_string().contains(bpath.to_str().unwrap()), "{err}");

        // bad magic
        let mut mbytes = bbytes.clone();
        mbytes[0] ^= 0xFF;
        std::fs::write(&bpath, &mbytes).unwrap();
        assert!(read_block(&dir, 0, Axis::Row).is_err());

        // missing rank file
        std::fs::write(&bpath, &bbytes).unwrap();
        assert!(read_block(&dir, 5, Axis::Row).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn balanced_shard_dir_roundtrips_partitions_and_balances_nnz() {
        use crate::data::partition::weight_balanced_partition;
        let mut rng = crate::rng::Pcg64::new(91, 0);
        // power-law column weights (Zipf): the first columns hold most nnz
        let sp = crate::data::synth::power_law_sparse(80, 120, 4000, 4, 1.0, &mut rng);
        let m = Matrix::Sparse(sp);
        let nodes = 3;
        let balanced = weight_balanced_partition(&col_nnz_counts(&m), nodes);
        let mut manifest = manifest_for(&m, nodes, "SKEWED");
        manifest.col_bounds = balanced.bounds();
        assert!(manifest.is_balanced());
        let dir = tmpdir("balanced");
        write_shard_dir(&dir, &m, &manifest).unwrap();
        let back = read_manifest(&dir).unwrap();
        assert_eq!(back.col_bounds, balanced.bounds());
        assert_eq!(back.col_partition(), balanced);

        // the LoadStats contract: per-party resident nnz is now comparable,
        // whereas uniform column cuts leave a >2x spread on this input
        let nnz_of = |dir: &Path, rank| {
            let (data, _) = NodeData::load(dir, rank, false, true).unwrap();
            data.load_stats(rank, 0.0, LoadSource::FileShard).nnz
        };
        let bal: Vec<usize> = (0..nodes).map(|r| nnz_of(&dir, r)).collect();
        let (bmin, bmax) = (*bal.iter().min().unwrap(), *bal.iter().max().unwrap());
        assert!(
            (bmax as f64) < 1.6 * bmin as f64,
            "balanced shards must spread nnz evenly: {bal:?}"
        );
        let udir = tmpdir("uniform_skew");
        write_shard_dir(&udir, &m, &manifest_for(&m, nodes, "SKEWED")).unwrap();
        let uni: Vec<usize> = (0..nodes).map(|r| nnz_of(&udir, r)).collect();
        let (umin, umax) = (*uni.iter().min().unwrap(), *uni.iter().max().unwrap());
        assert!(
            umax as f64 > 2.0 * umin.max(1) as f64,
            "the skewed input should be imbalanced under uniform cuts: {uni:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&udir).ok();
    }

    #[test]
    fn shard_spec_partitions_cover() {
        for total in [10usize, 101] {
            for nodes in [1usize, 3, 7] {
                let mut covered = 0;
                for rank in 0..nodes {
                    let s = ShardSpec::uniform(Axis::Row, rank, nodes, total);
                    assert_eq!(s.range.start, covered, "ranges must be rank-ordered");
                    covered = s.range.end;
                }
                assert_eq!(covered, total);
            }
        }
    }

    #[test]
    fn codes_roundtrip() {
        for a in [Axis::Row, Axis::Col] {
            assert_eq!(Axis::from_code(a.code()).unwrap(), a);
        }
        assert!(Axis::from_code(9).is_err());
        for s in [
            LoadSource::FullMatrix,
            LoadSource::SynthShard,
            LoadSource::FileShard,
            LoadSource::CompressedShard,
        ] {
            assert_eq!(LoadSource::from_code(s.code()).unwrap(), s);
        }
        assert!(LoadSource::from_code(9).is_err());
    }
}
