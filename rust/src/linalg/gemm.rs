//! Cache-blocked, multi-threaded GEMM kernels (f32, row-major).
//!
//! Three variants cover every product in the NMF algorithms:
//!
//! * [`gemm_nn`]  — `C = A·B`        (e.g. `U · (VᵀS)` reconstruction)
//! * [`gemm_nt`]  — `C = A·Bᵀ`       (e.g. `A_r Bᵀ`, `B Bᵀ` gram)
//! * [`gemm_tn`]  — `C = Aᵀ·B`       (e.g. `V_{J_r}ᵀ S_{J_r}` sketch summand)
//!
//! Strategy: `nn`/`nt` parallelise over row panels of `C` (disjoint `&mut`
//! chunks), with k-blocking so the active B panel stays in L1/L2; `tn`
//! accumulates thread-local partials over row ranges of A (its output is
//! small — k×d or k×k — so the final reduction is cheap).

use super::Mat;
use crate::parallel;

/// Rows of C handled per parallel task.
const ROW_CHUNK: usize = 64;
/// k-dimension blocking factor.
const KBLOCK: usize = 256;

/// `out = a · b` where `a: m×k`, `b: k×n`, `out: m×n` (overwritten).
pub fn gemm_nn(a: &Mat, b: &Mat, out: &mut Mat) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!((out.rows(), out.cols()), (m, n));
    let a_data = a.data();
    let b_data = b.data();
    parallel::par_chunks_mut(out.data_mut(), ROW_CHUNK * n, |chunk_idx, c_chunk| {
        c_chunk.fill(0.0);
        let i0 = chunk_idx * ROW_CHUNK;
        let rows_here = c_chunk.len() / n;
        for kb in (0..k).step_by(KBLOCK) {
            let kend = (kb + KBLOCK).min(k);
            for li in 0..rows_here {
                let i = i0 + li;
                let a_row = &a_data[i * k..(i + 1) * k];
                let c_row = &mut c_chunk[li * n..(li + 1) * n];
                for kk in kb..kend {
                    let aik = a_row[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let b_row = &b_data[kk * n..(kk + 1) * n];
                    // i-k-j: unit-stride axpy over the C row.
                    for (c, &bv) in c_row.iter_mut().zip(b_row.iter()) {
                        *c += aik * bv;
                    }
                }
            }
        }
    });
}

/// `out = a · bᵀ` where `a: m×k`, `b: n×k`, `out: m×n` (overwritten).
///
/// §Perf: implemented as `transpose(b)` + [`gemm_nn`]. The dot-product
/// formulation ran at ~4.7 GFLOP/s (strict-FP scalar reduction defeats
/// auto-vectorisation); the i-k-j axpy kernel of `gemm_nn` runs at
/// ~17 GFLOP/s, and in every hot call site (`normal_from`: `A·Bᵀ`, `B·Bᵀ`)
/// the transposed operand is the small `k×d` factor, so the O(nk)
/// transpose is noise. Measured 3.4× end-to-end on the microbench
/// (EXPERIMENTS.md §Perf).
pub fn gemm_nt(a: &Mat, b: &Mat, out: &mut Mat) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    assert_eq!(b.cols(), k);
    assert_eq!((out.rows(), out.cols()), (m, n));
    if n <= 4 {
        // tiny output width: dot products beat transpose+axpy
        let a_data = a.data();
        let b_data = b.data();
        parallel::par_chunks_mut(out.data_mut(), ROW_CHUNK * n, |chunk_idx, c_chunk| {
            let i0 = chunk_idx * ROW_CHUNK;
            let rows_here = c_chunk.len() / n;
            for li in 0..rows_here {
                let i = i0 + li;
                let a_row = &a_data[i * k..(i + 1) * k];
                let c_row = &mut c_chunk[li * n..(li + 1) * n];
                for (j, c) in c_row.iter_mut().enumerate() {
                    *c = dot(a_row, &b_data[j * k..(j + 1) * k]);
                }
            }
        });
        return;
    }
    let bt = b.transpose(); // k×n
    gemm_nn(a, &bt, out);
}

/// `out = aᵀ · b` where `a: m×k`, `b: m×n`, `out: k×n` (overwritten).
pub fn gemm_tn(a: &Mat, b: &Mat, out: &mut Mat) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), m);
    assert_eq!((out.rows(), out.cols()), (k, n));
    let a_data = a.data();
    let b_data = b.data();
    let nparts = parallel::num_threads().min(m.div_ceil(ROW_CHUNK)).max(1);
    // Thread-local partial k×n accumulators over disjoint row ranges of A/B.
    let partials = parallel::par_map(nparts, |p| {
        let ranges = parallel::split_ranges(m, nparts);
        let r = ranges[p].clone();
        let mut part = vec![0.0f32; k * n];
        for row in r {
            let a_row = &a_data[row * k..(row + 1) * k];
            let b_row = &b_data[row * n..(row + 1) * n];
            for (i, &av) in a_row.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let c_row = &mut part[i * n..(i + 1) * n];
                for (c, &bv) in c_row.iter_mut().zip(b_row.iter()) {
                    *c += av * bv;
                }
            }
        }
        part
    });
    let out_data = out.data_mut();
    out_data.fill(0.0);
    for part in partials {
        for (o, p) in out_data.iter_mut().zip(part.iter()) {
            *o += p;
        }
    }
}

/// Unrolled dot product (the `nt` microkernel).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn naive_nn(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for kk in 0..a.cols() {
                    s += (a.get(i, kk) as f64) * (b.get(kk, j) as f64);
                }
                c.set(i, j, s as f32);
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        for (x, y) in a.data().iter().zip(b.data().iter()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Pcg64::new(17, 0);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (64, 33, 65), (130, 17, 129)] {
            let a = Mat::rand_uniform(m, k, 1.0, &mut rng);
            let b = Mat::rand_uniform(k, n, 1.0, &mut rng);
            let expect = naive_nn(&a, &b);

            let mut c = Mat::zeros(m, n);
            gemm_nn(&a, &b, &mut c);
            assert_close(&c, &expect, 1e-4);

            let bt = b.transpose();
            let mut c2 = Mat::zeros(m, n);
            gemm_nt(&a, &bt, &mut c2);
            assert_close(&c2, &expect, 1e-4);

            let at = a.transpose();
            let mut c3 = Mat::zeros(m, n);
            gemm_tn(&at, &b, &mut c3);
            assert_close(&c3, &expect, 1e-4);
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Pcg64::new(23, 0);
        for len in [0usize, 1, 7, 8, 9, 31, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
            let naive: f32 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4);
        }
    }
}
