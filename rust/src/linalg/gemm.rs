//! Packed, register-blocked, explicit-SIMD GEMM kernels (f32, row-major).
//!
//! Three variants cover every product in the NMF algorithms:
//!
//! * [`gemm_nn`]  — `C = A·B`        (e.g. `U · (VᵀS)` reconstruction)
//! * [`gemm_nt`]  — `C = A·Bᵀ`       (e.g. `A_r Bᵀ`, `B Bᵀ` gram)
//! * [`gemm_tn`]  — `C = Aᵀ·B`       (e.g. `V_{J_r}ᵀ S_{J_r}` sketch summand)
//!
//! ## Strategy
//!
//! `nn`/`nt` run a BLIS-style packed kernel: operand blocks are copied into
//! contiguous scratch — A into `MR`-row panels, B into `NR`-column panels —
//! then an `MR×NR` register-tiled microkernel sweeps the k-block. The
//! microkernel is explicit AVX2+FMA (`f32x8`, 6×16 tile, 12 accumulator
//! registers) with a portable unrolled fallback, dispatched at runtime via
//! `is_x86_feature_detected!` (override with `DSANLS_SIMD=portable` or
//! [`set_force_portable`] for A/B tests). **`gemm_nt` transposes nothing**:
//! the B-packing routine reads `Bᵀ` straight out of the row-major `B`, so
//! the seed's `transpose(B)` + `gemm_nn` workaround (an O(nk) copy per
//! call) is folded into packing.
//!
//! Parallelism: row panels of `C` (disjoint `&mut` chunks) on the
//! persistent pool of [`crate::parallel`]. Packing scratch lives in
//! thread-local buffers that the pool's long-lived workers reuse, so the
//! kernels themselves perform **zero heap allocation** in steady state —
//! measured single-threaded by `tests/alloc_hotpath.rs`. (Multithreaded
//! calls additionally pay one `Arc`-based job handle per parallel region
//! in [`crate::parallel`] — dispatch bookkeeping, not per-element
//! traffic.)
//!
//! `tn` has a small `k×n` output (k and n are the factorisation rank /
//! sketch size) but a long `m` reduction, so register tiling over the
//! output cannot pay; it instead parallelises the reduction over row
//! ranges with per-part partial accumulators and an explicit-SIMD
//! [`saxpy`] inner loop. The multithreaded `tn` path allocates its
//! (small, `k×n`) partials per call; single-threaded `tn` writes straight
//! into `out` and allocates nothing.
//!
//! §Perf: seed scalar i-k-j kernel ≈ 17 GFLOP/s on 1024³ `gemm_nn`; the
//! packed AVX2 path is ≥ 2× that (see EXPERIMENTS.md §Perf and
//! `benches/microbench_gemm.rs`, which emits `BENCH_gemm.json`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use super::Mat;
use crate::parallel;

/// Microkernel tile rows (A panel height).
const MR: usize = 6;
/// Microkernel tile columns (B panel width) — two f32x8 vectors.
const NR: usize = 16;
/// k-dimension cache block (A/B panel depth); sized for L1/L2 residency.
const KC: usize = 256;
/// Row block per parallel task (multiple of `MR`).
const MC: usize = 72;
/// Column cache block (multiple of `NR`).
const NC: usize = 512;
/// Below this `m·n·k`, packing overhead dominates — use the naive loop.
const SMALL_GEMM: usize = 32 * 32 * 32;
/// Rows of C per parallel task in the `nt` dot fast path.
const ROW_CHUNK: usize = 64;

// ---------------------------------------------------------------------------
// SIMD dispatch
// ---------------------------------------------------------------------------

static FORCE_PORTABLE: AtomicBool = AtomicBool::new(false);

fn init_simd_env() {
    static ONCE: OnceLock<()> = OnceLock::new();
    ONCE.get_or_init(|| {
        if std::env::var("DSANLS_SIMD").map(|v| v == "portable").unwrap_or(false) {
            FORCE_PORTABLE.store(true, Ordering::Relaxed);
        }
    });
}

/// True when the AVX2+FMA microkernel is compiled in, detected at runtime,
/// and not overridden.
fn use_avx2() -> bool {
    init_simd_env();
    if FORCE_PORTABLE.load(Ordering::Relaxed) {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        static DETECTED: OnceLock<bool> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Force the portable (non-intrinsic) kernels, e.g. for dispatch-path
/// equivalence tests and `DSANLS_SIMD=portable` A/B benchmarking.
pub fn set_force_portable(on: bool) {
    init_simd_env();
    FORCE_PORTABLE.store(on, Ordering::Relaxed);
}

/// Which inner-kernel path the next GEMM call will take.
pub fn simd_path() -> &'static str {
    if use_avx2() {
        "avx2-fma"
    } else {
        "portable"
    }
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// How a packing routine reads its source matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Layout {
    /// Element `(i, j)` is `src[i * ld + j]`.
    RowMajor,
    /// Element `(i, j)` is `src[j * ld + i]` — a transposed *view*, used to
    /// fold `gemm_nt`'s `Bᵀ` into packing without materialising it.
    Transposed,
}

#[inline(always)]
fn elem(src: &[f32], ld: usize, layout: Layout, i: usize, j: usize) -> f32 {
    match layout {
        Layout::RowMajor => src[i * ld + j],
        Layout::Transposed => src[j * ld + i],
    }
}

/// Pack rows `i0..i0+mc` × cols `p0..p0+kc` of the A view into `MR`-row
/// panels: `dst[panel*kc*MR + p*MR + r]`, zero-padded to a full `MR`.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    dst: &mut [f32],
    a: &[f32],
    lda: usize,
    layout: Layout,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
) {
    let mut off = 0;
    let mut ir = 0;
    while ir < mc {
        let mr = MR.min(mc - ir);
        for p in 0..kc {
            let col = &mut dst[off + p * MR..off + (p + 1) * MR];
            for (r, slot) in col.iter_mut().enumerate() {
                *slot = if r < mr { elem(a, lda, layout, i0 + ir + r, p0 + p) } else { 0.0 };
            }
        }
        off += kc * MR;
        ir += MR;
    }
}

/// Pack rows `p0..p0+kc` × cols `j0..j0+nc` of the B view into `NR`-column
/// panels: `dst[panel*kc*NR + p*NR + j]`, zero-padded to a full `NR`.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    dst: &mut [f32],
    b: &[f32],
    ldb: usize,
    layout: Layout,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
) {
    let mut off = 0;
    let mut jr = 0;
    while jr < nc {
        let nr = NR.min(nc - jr);
        for p in 0..kc {
            let row = &mut dst[off + p * NR..off + (p + 1) * NR];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = if j < nr { elem(b, ldb, layout, p0 + p, j0 + jr + j) } else { 0.0 };
            }
        }
        off += kc * NR;
        jr += NR;
    }
}

// ---------------------------------------------------------------------------
// Microkernels: acc (MR×NR, zero-initialised by the caller) += A~ · B~
// ---------------------------------------------------------------------------

#[inline(always)]
fn micro_kernel_portable(kc: usize, a: &[f32], b: &[f32], acc: &mut [f32; MR * NR]) {
    for p in 0..kc {
        let ap = &a[p * MR..(p + 1) * MR];
        let bp = &b[p * NR..(p + 1) * NR];
        for r in 0..MR {
            let ar = ap[r];
            let row = &mut acc[r * NR..(r + 1) * NR];
            for (c, &bv) in row.iter_mut().zip(bp.iter()) {
                *c += ar * bv;
            }
        }
    }
}

/// 6×16 AVX2+FMA tile: 12 ymm accumulators, 2 B vectors, 1 broadcast.
///
/// # Safety
/// Caller must have verified AVX2+FMA support (see [`use_avx2`]). `a` must
/// hold `kc*MR` floats, `b` `kc*NR` floats.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn micro_kernel_avx2(kc: usize, a: &[f32], b: &[f32], acc: &mut [f32; MR * NR]) {
    use std::arch::x86_64::*;
    debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
    let mut c: [[__m256; 2]; MR] = [[_mm256_setzero_ps(); 2]; MR];
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    for p in 0..kc {
        let b0 = _mm256_loadu_ps(bp.add(p * NR));
        let b1 = _mm256_loadu_ps(bp.add(p * NR + 8));
        for (r, cr) in c.iter_mut().enumerate() {
            let ar = _mm256_set1_ps(*ap.add(p * MR + r));
            cr[0] = _mm256_fmadd_ps(ar, b0, cr[0]);
            cr[1] = _mm256_fmadd_ps(ar, b1, cr[1]);
        }
    }
    for (r, cr) in c.iter().enumerate() {
        _mm256_storeu_ps(acc.as_mut_ptr().add(r * NR), cr[0]);
        _mm256_storeu_ps(acc.as_mut_ptr().add(r * NR + 8), cr[1]);
    }
}

/// `y += alpha · x`, explicit AVX2+FMA with portable fallback. Shared by
/// `gemm_tn`'s reduction and the sparse SpMM kernels
/// ([`crate::linalg::Csr::spmm`] / `spmm_tn`).
#[inline]
pub fn saxpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    saxpy_dispatch(use_avx2(), alpha, x, y);
}

/// [`saxpy`] with the SIMD decision hoisted by the caller — `gemm_tn`
/// resolves dispatch once per GEMM instead of once per nonzero element.
#[inline]
fn saxpy_dispatch(simd: bool, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if simd && x.len() >= 16 {
        // SAFETY: `simd` is only true after use_avx2() detection
        unsafe { saxpy_avx2(alpha, x, y) };
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = simd;
    for (yv, &xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * xv;
    }
}

/// # Safety
/// Caller must have verified AVX2+FMA support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn saxpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let av = _mm256_set1_ps(alpha);
    let mut i = 0;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(xp.add(i));
        let yv = _mm256_loadu_ps(yp.add(i));
        _mm256_storeu_ps(yp.add(i), _mm256_fmadd_ps(av, xv, yv));
        i += 8;
    }
    while i < n {
        *yp.add(i) += alpha * *xp.add(i);
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Macro kernel + packed driver
// ---------------------------------------------------------------------------

/// One cache block: `C[0..mc, jc..jc+nc] += A~ · B~` over a `kc` depth.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    abuf: &[f32],
    bbuf: &[f32],
    kc: usize,
    mc: usize,
    nc: usize,
    c: &mut [f32],
    ldc: usize,
    jc: usize,
    simd: bool,
) {
    let mut ir = 0;
    while ir < mc {
        let mr = MR.min(mc - ir);
        let a_panel = &abuf[(ir / MR) * kc * MR..][..kc * MR];
        let mut jr = 0;
        while jr < nc {
            let nr = NR.min(nc - jr);
            let b_panel = &bbuf[(jr / NR) * kc * NR..][..kc * NR];
            let mut acc = [0.0f32; MR * NR];
            #[cfg(target_arch = "x86_64")]
            if simd {
                // SAFETY: `simd` is only true after use_avx2() detection
                unsafe { micro_kernel_avx2(kc, a_panel, b_panel, &mut acc) };
            } else {
                micro_kernel_portable(kc, a_panel, b_panel, &mut acc);
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                let _ = simd;
                micro_kernel_portable(kc, a_panel, b_panel, &mut acc);
            }
            for r in 0..mr {
                let crow = &mut c[(ir + r) * ldc + jc + jr..][..nr];
                for (cv, &av) in crow.iter_mut().zip(acc[r * NR..r * NR + nr].iter()) {
                    *cv += av;
                }
            }
            jr += NR;
        }
        ir += MR;
    }
}

thread_local! {
    /// Per-worker packing scratch. Pool workers are persistent, so these
    /// amortise to zero allocations in steady state.
    static A_PACK: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
    static B_PACK: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Packed driver: `C (m×n, overwritten) = Aview (m×k) · Bview (k×n)`.
///
/// BLIS loop order: for each `(jc, pc)` cache block the submitting thread
/// packs B **once** into its thread-local scratch, then the `MC`-row
/// chunks of C fan out across the pool, each worker packing its own A
/// panel. (Packing B per row chunk instead would duplicate the B copy
/// `m/MC` times per call.) A is re-packed per `jc` block; with
/// `NC = 512` that is one extra A pass only for very wide `n`.
#[allow(clippy::too_many_arguments)]
fn gemm_packed(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    a_layout: Layout,
    b: &[f32],
    ldb: usize,
    b_layout: Layout,
    c: &mut [f32],
) {
    debug_assert_eq!(c.len(), m * n);
    let simd = use_avx2();
    B_PACK.with(|bpc| {
        let mut bbuf = bpc.borrow_mut();
        let b_need = KC * NC;
        if bbuf.len() < b_need {
            bbuf.resize(b_need, 0.0);
        }
        let mut jc = 0;
        while jc < n {
            let nc = NC.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kc = KC.min(k - pc);
                pack_b(&mut bbuf, b, ldb, b_layout, pc, kc, jc, nc);
                let bref: &[f32] = &bbuf[..];
                let zero_first = jc == 0 && pc == 0;
                parallel::par_chunks_mut(c, MC * n, |chunk_idx, c_chunk| {
                    let i0 = chunk_idx * MC;
                    let mc = c_chunk.len() / n;
                    if zero_first {
                        c_chunk.fill(0.0);
                    }
                    A_PACK.with(|apc| {
                        let mut abuf = apc.borrow_mut();
                        let a_need = mc.div_ceil(MR) * MR * KC;
                        if abuf.len() < a_need {
                            abuf.resize(a_need, 0.0);
                        }
                        pack_a(&mut abuf, a, lda, a_layout, i0, mc, pc, kc);
                        macro_kernel(&abuf, bref, kc, mc, nc, c_chunk, n, jc, simd);
                    });
                });
                pc += KC;
            }
            jc += NC;
        }
    });
}

/// Serial naive kernel for tiny problems where packing cannot pay.
#[allow(clippy::too_many_arguments)]
fn gemm_naive(m: usize, n: usize, k: usize, a: &[f32], lda: usize, a_layout: Layout, b: &[f32], ldb: usize, b_layout: Layout, c: &mut [f32]) {
    c.fill(0.0);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = elem(a, lda, a_layout, i, p);
            if av == 0.0 {
                continue;
            }
            match b_layout {
                Layout::RowMajor => {
                    let brow = &b[p * ldb..p * ldb + n];
                    for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += av * bv;
                    }
                }
                Layout::Transposed => {
                    for (j, cv) in crow.iter_mut().enumerate() {
                        *cv += av * b[j * ldb + p];
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------------

/// `out = a · b` where `a: m×k`, `b: k×n`, `out: m×n` (overwritten).
pub fn gemm_nn(a: &Mat, b: &Mat, out: &mut Mat) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!((out.rows(), out.cols()), (m, n));
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.data_mut().fill(0.0);
        return;
    }
    if m * n * k <= SMALL_GEMM {
        gemm_naive(m, n, k, a.data(), k, Layout::RowMajor, b.data(), n, Layout::RowMajor, out.data_mut());
        return;
    }
    gemm_packed(m, n, k, a.data(), k, Layout::RowMajor, b.data(), n, Layout::RowMajor, out.data_mut());
}

/// `out = a · bᵀ` where `a: m×k`, `b: n×k`, `out: m×n` (overwritten).
///
/// §Perf: the transposed operand is read directly by the packing routine
/// (`Layout::Transposed`), so no `k×n` transpose is materialised — the
/// seed's `transpose(b)` + `gemm_nn` detour is gone. For very narrow
/// outputs (`n ≤ 8`, e.g. the `rows×k` cross-products against a small
/// factor) a parallel dot-product path is faster than packing.
pub fn gemm_nt(a: &Mat, b: &Mat, out: &mut Mat) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    assert_eq!(b.cols(), k);
    assert_eq!((out.rows(), out.cols()), (m, n));
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.data_mut().fill(0.0);
        return;
    }
    if n <= 8 && m * n * k > SMALL_GEMM {
        // narrow output: dot products over rows of a × rows of b
        let a_data = a.data();
        let b_data = b.data();
        parallel::par_chunks_mut(out.data_mut(), ROW_CHUNK * n, |chunk_idx, c_chunk| {
            let i0 = chunk_idx * ROW_CHUNK;
            let rows_here = c_chunk.len() / n;
            for li in 0..rows_here {
                let i = i0 + li;
                let a_row = &a_data[i * k..(i + 1) * k];
                let c_row = &mut c_chunk[li * n..(li + 1) * n];
                for (j, c) in c_row.iter_mut().enumerate() {
                    *c = dot(a_row, &b_data[j * k..(j + 1) * k]);
                }
            }
        });
        return;
    }
    if m * n * k <= SMALL_GEMM {
        gemm_naive(m, n, k, a.data(), k, Layout::RowMajor, b.data(), k, Layout::Transposed, out.data_mut());
        return;
    }
    gemm_packed(m, n, k, a.data(), k, Layout::RowMajor, b.data(), k, Layout::Transposed, out.data_mut());
}

/// `out = aᵀ · b` where `a: m×k`, `b: m×n`, `out: k×n` (overwritten).
///
/// The output is small (`k`, `n` are rank/sketch sizes) but the reduction
/// dimension `m` is long, so this parallelises over row ranges of `a`/`b`
/// with thread-local `k×n` partials and a SIMD [`saxpy`] inner loop, then
/// sums the partials in part order (deterministic).
pub fn gemm_tn(a: &Mat, b: &Mat, out: &mut Mat) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), m);
    assert_eq!((out.rows(), out.cols()), (k, n));
    if k == 0 || n == 0 {
        return;
    }
    let out_data = out.data_mut();
    if m == 0 {
        out_data.fill(0.0);
        return;
    }
    let a_data = a.data();
    let b_data = b.data();
    let simd = use_avx2(); // resolve dispatch once, not per nonzero element
    let nparts = parallel::num_threads().min(m.div_ceil(128)).max(1);
    if nparts == 1 {
        out_data.fill(0.0);
        tn_accumulate(simd, a_data, b_data, k, n, 0..m, out_data);
        return;
    }
    let ranges = parallel::split_ranges(m, nparts);
    let partials = parallel::par_map(nparts, |p| {
        let mut part = vec![0.0f32; k * n];
        tn_accumulate(simd, a_data, b_data, k, n, ranges[p].clone(), &mut part);
        part
    });
    out_data.fill(0.0);
    for part in partials {
        saxpy_dispatch(simd, 1.0, &part, out_data);
    }
}

/// `acc (k×n) += Aᵀ·B` over the given row range.
#[allow(clippy::too_many_arguments)]
fn tn_accumulate(
    simd: bool,
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    rows: std::ops::Range<usize>,
    acc: &mut [f32],
) {
    for row in rows {
        let a_row = &a[row * k..(row + 1) * k];
        let b_row = &b[row * n..(row + 1) * n];
        for (i, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            saxpy_dispatch(simd, av, b_row, &mut acc[i * n..(i + 1) * n]);
        }
    }
}

/// Unrolled dot product (narrow-output microkernel, also used by the
/// sparse loss and the CD solver sweep).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn naive_nn(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for kk in 0..a.cols() {
                    s += (a.get(i, kk) as f64) * (b.get(kk, j) as f64);
                }
                c.set(i, j, s as f32);
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        for (x, y) in a.data().iter().zip(b.data().iter()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    /// All three variants against the f64 naive reference on one shape.
    fn check_shape(m: usize, k: usize, n: usize, rng: &mut Pcg64) {
        let a = Mat::rand_uniform(m, k, 1.0, rng);
        let b = Mat::rand_uniform(k, n, 1.0, rng);
        let expect = naive_nn(&a, &b);

        let mut c = Mat::zeros(m, n);
        gemm_nn(&a, &b, &mut c);
        assert_close(&c, &expect, 1e-4);

        let bt = b.transpose();
        let mut c2 = Mat::zeros(m, n);
        gemm_nt(&a, &bt, &mut c2);
        assert_close(&c2, &expect, 1e-4);

        let at = a.transpose();
        let mut c3 = Mat::zeros(m, n);
        gemm_tn(&at, &b, &mut c3);
        assert_close(&c3, &expect, 1e-4);
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Pcg64::new(17, 0);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (64, 33, 65), (130, 17, 129)] {
            check_shape(m, k, n, &mut rng);
        }
    }

    #[test]
    fn gemm_edge_shapes_match_naive() {
        // non-multiple-of-block dims around MR=6/NR=16/KC=256, single
        // rows/cols, and tall-skinny m ≫ k
        let mut rng = Pcg64::new(19, 1);
        for &(m, k, n) in &[
            (127usize, 63usize, 255usize), // odd everything, k spills no KC block
            (6, 16, 16),                   // exactly one microtile
            (7, 17, 17),                   // one microtile + 1 edge everywhere
            (72, 256, 512),                // exactly one (MC, KC, NC) block
            (73, 257, 33),                 // one block + 1
            (5, 1, 5),                     // k = 1
            (1, 128, 9),                   // single row
            (97, 300, 1),                  // single col (k past one KC block)
            (500, 3, 5),                   // tall-skinny m ≫ k
            (600, 40, 2),                  // narrow-output nt fast path
        ] {
            check_shape(m, k, n, &mut rng);
        }
    }

    #[test]
    fn gemm_zero_sized_dims_are_guarded() {
        let mut rng = Pcg64::new(23, 2);
        // k = 0: product must be all zeros (and not panic)
        let a = Mat::zeros(4, 0);
        let b = Mat::zeros(0, 3);
        let mut c = Mat::rand_uniform(4, 3, 1.0, &mut rng);
        gemm_nn(&a, &b, &mut c);
        assert!(c.data().iter().all(|&v| v == 0.0));
        let mut c2 = Mat::rand_uniform(4, 0, 1.0, &mut rng);
        let bt = Mat::zeros(0, 0);
        gemm_nt(&a, &bt, &mut c2); // n = 0 and k = 0
        // m = 0 rows
        let a0 = Mat::zeros(0, 5);
        let b5 = Mat::zeros(5, 3);
        let mut c0 = Mat::zeros(0, 3);
        gemm_nn(&a0, &b5, &mut c0);
        // tn with zero reduction length
        let mut g = Mat::rand_uniform(5, 3, 1.0, &mut rng);
        gemm_tn(&a0, &Mat::zeros(0, 3), &mut g);
        assert!(g.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn simd_and_portable_paths_agree() {
        // exercise both dispatch paths against the f64 reference; on
        // machines without AVX2 both runs take the portable kernel and the
        // test degenerates to a (still valid) regression check
        let mut rng = Pcg64::new(29, 3);
        let (m, k, n) = (151, 93, 70);
        let a = Mat::rand_uniform(m, k, 1.0, &mut rng);
        let b = Mat::rand_uniform(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let at = a.transpose();
        let expect = naive_nn(&a, &b);

        for force_portable in [true, false] {
            set_force_portable(force_portable);
            let mut c = Mat::zeros(m, n);
            gemm_nn(&a, &b, &mut c);
            assert_close(&c, &expect, 1e-4);
            let mut c2 = Mat::zeros(m, n);
            gemm_nt(&a, &bt, &mut c2);
            assert_close(&c2, &expect, 1e-4);
            let mut c3 = Mat::zeros(m, n);
            gemm_tn(&at, &b, &mut c3);
            assert_close(&c3, &expect, 1e-4);
        }
        set_force_portable(false);
    }

    #[test]
    fn saxpy_matches_scalar() {
        let mut rng = Pcg64::new(37, 4);
        for len in [0usize, 1, 7, 8, 15, 16, 17, 100, 1000] {
            let x: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
            let mut y: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
            let mut y_ref = y.clone();
            let alpha = 0.37f32;
            saxpy(alpha, &x, &mut y);
            for (yv, &xv) in y_ref.iter_mut().zip(x.iter()) {
                *yv += alpha * xv;
            }
            for (a, b) in y.iter().zip(y_ref.iter()) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Pcg64::new(23, 0);
        for len in [0usize, 1, 7, 8, 9, 31, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
            let naive: f32 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4);
        }
    }
}
