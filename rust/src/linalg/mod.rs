//! Dense and sparse linear algebra substrate (f32, row-major).
//!
//! Built from scratch (no BLAS available offline): a packed,
//! register-blocked, explicit-SIMD GEMM ([`gemm`] — AVX2/FMA microkernel
//! with a portable fallback, runtime-dispatched), a row-major dense
//! [`Mat`], and a CSR sparse matrix [`Csr`] with the SpMM variants the NMF
//! algorithms need. Parallel loops run on the persistent worker pool of
//! [`crate::parallel`]; GEMM packing scratch is thread-local and reused
//! across calls, so steady-state products allocate nothing.
//!
//! Everything is `f32`: it matches the AOT XLA artifacts, halves memory
//! traffic versus f64 (NMF is memory-bound), and the paper's MKL baseline
//! operates in single precision as well.

mod dense;
mod gemm;
mod sparse;

pub use dense::Mat;
pub use gemm::{dot, gemm_nn, gemm_nt, gemm_tn, saxpy, set_force_portable, simd_path};
pub use sparse::Csr;

/// Either a dense or a sparse input matrix `M`. The NMF algorithms are
/// generic over this: sketching and loss evaluation dispatch on the variant
/// (sparse paths never densify `M`).
#[derive(Debug, Clone)]
pub enum Matrix {
    Dense(Mat),
    Sparse(Csr),
}

impl Matrix {
    pub fn rows(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.rows(),
            Matrix::Sparse(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.cols(),
            Matrix::Sparse(m) => m.cols(),
        }
    }

    /// Number of explicitly stored values.
    pub fn nnz(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.rows() * m.cols(),
            Matrix::Sparse(m) => m.nnz(),
        }
    }

    /// Squared Frobenius norm.
    pub fn fro_sq(&self) -> f64 {
        match self {
            Matrix::Dense(m) => m.fro_sq(),
            Matrix::Sparse(m) => m.values().iter().map(|&v| (v as f64) * (v as f64)).sum(),
        }
    }

    /// Extract the row block `rows` as a new matrix of the same kind.
    pub fn row_block(&self, rows: std::ops::Range<usize>) -> Matrix {
        match self {
            Matrix::Dense(m) => Matrix::Dense(m.row_block(rows)),
            Matrix::Sparse(m) => Matrix::Sparse(m.row_block(rows)),
        }
    }

    /// Extract the column block `cols` as a new matrix of the same kind.
    pub fn col_block(&self, cols: std::ops::Range<usize>) -> Matrix {
        match self {
            Matrix::Dense(m) => Matrix::Dense(m.col_block(cols.clone())),
            Matrix::Sparse(m) => Matrix::Sparse(m.col_block(cols)),
        }
    }

    /// Transpose (materialised).
    pub fn transpose(&self) -> Matrix {
        match self {
            Matrix::Dense(m) => Matrix::Dense(m.transpose()),
            Matrix::Sparse(m) => Matrix::Sparse(m.transpose()),
        }
    }

    /// Densify (tests / small matrices only).
    pub fn to_dense(&self) -> Mat {
        match self {
            Matrix::Dense(m) => m.clone(),
            Matrix::Sparse(m) => m.to_dense(),
        }
    }
}

impl From<Mat> for Matrix {
    fn from(m: Mat) -> Self {
        Matrix::Dense(m)
    }
}

impl From<Csr> for Matrix {
    fn from(m: Csr) -> Self {
        Matrix::Sparse(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_enum_dispatch() {
        let d = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let s = Csr::from_dense(&d, 0.0);
        let md: Matrix = d.clone().into();
        let ms: Matrix = s.into();
        assert_eq!(md.rows(), 2);
        assert_eq!(ms.cols(), 2);
        assert!((md.fro_sq() - 30.0).abs() < 1e-6);
        assert!((ms.fro_sq() - 30.0).abs() < 1e-6);
        assert_eq!(ms.to_dense().data(), d.data());
        let t = ms.transpose().to_dense();
        assert_eq!(t.get(0, 1), 3.0);
    }
}
