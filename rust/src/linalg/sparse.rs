//! CSR sparse matrix and the SpMM variants the NMF algorithms need.
//!
//! The paper evaluates on sparse text/graph matrices (RCV1 99.84 % sparse,
//! DBLP 99.998 % sparse); the subsampling sketch "can preserve the sparsity
//! of the original matrix" (Sec. 3.4), so all sketch/loss paths here operate
//! on nonzeros only and never densify `M`.

use super::{gemm, Mat};
use crate::parallel;

/// Compressed sparse row matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Row pointers, length `rows + 1`.
    indptr: Vec<usize>,
    /// Column indices per nonzero (sorted within each row).
    indices: Vec<usize>,
    /// Values per nonzero.
    values: Vec<f32>,
}

impl std::fmt::Debug for Csr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Csr({}x{}, nnz={})", self.rows, self.cols, self.nnz())
    }
}

impl Csr {
    /// Build from COO triplets (row, col, value). Duplicates are summed in
    /// insertion order (stable sort), so building from any filtered subset
    /// of a triplet stream merges cells exactly like the full build — the
    /// property the windowed shard generators rely on for bit-identity.
    pub fn from_triplets(rows: usize, cols: usize, mut t: Vec<(usize, usize, f32)>) -> Self {
        t.sort_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(t.len());
        let mut values: Vec<f32> = Vec::with_capacity(t.len());
        let mut last: Option<(usize, usize)> = None;
        for (r, c, v) in t {
            assert!(r < rows && c < cols, "triplet out of bounds");
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v; // merge duplicates
            } else {
                indices.push(c);
                values.push(v);
                indptr[r + 1] += 1; // per-row count for now
                last = Some((r, c));
            }
        }
        for r in 1..=rows {
            indptr[r] += indptr[r - 1]; // counts → cumulative offsets
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Rebuild from raw CSR parts (shard-file deserialisation). Validates
    /// the structural invariants so a corrupt block file surfaces as an
    /// error instead of undefined downstream behaviour.
    pub fn from_raw_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f32>,
    ) -> crate::error::Result<Self> {
        if indptr.len() != rows + 1 || indptr.first() != Some(&0) {
            crate::bail!("csr indptr length {} for {rows} rows", indptr.len());
        }
        if indptr.windows(2).any(|w| w[1] < w[0]) {
            crate::bail!("csr indptr is not monotone");
        }
        if *indptr.last().unwrap() != values.len() || indices.len() != values.len() {
            crate::bail!(
                "csr nnz mismatch: indptr says {}, {} indices, {} values",
                indptr.last().unwrap(),
                indices.len(),
                values.len()
            );
        }
        if indices.iter().any(|&j| j >= cols) {
            crate::bail!("csr column index out of bounds (cols = {cols})");
        }
        Ok(Csr { rows, cols, indptr, indices, values })
    }

    /// Densify → CSR, dropping entries with |v| ≤ `tol`.
    pub fn from_dense(m: &Mat, tol: f32) -> Self {
        let mut indptr = vec![0usize; m.rows() + 1];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v.abs() > tol {
                    indices.push(j);
                    values.push(v);
                }
            }
            indptr[i + 1] = indices.len();
        }
        Csr { rows: m.rows(), cols: m.cols(), indptr, indices, values }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn values(&self) -> &[f32] {
        &self.values
    }

    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    /// Density in [0, 1].
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Iterator over `(col, value)` of row `i`.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let r = self.indptr[i]..self.indptr[i + 1];
        self.indices[r.clone()].iter().copied().zip(self.values[r].iter().copied())
    }

    /// Row block as a new CSR.
    pub fn row_block(&self, r: std::ops::Range<usize>) -> Csr {
        assert!(r.end <= self.rows);
        let lo = self.indptr[r.start];
        let hi = self.indptr[r.end];
        let indptr = self.indptr[r.start..=r.end].iter().map(|&p| p - lo).collect();
        Csr {
            rows: r.len(),
            cols: self.cols,
            indptr,
            indices: self.indices[lo..hi].to_vec(),
            values: self.values[lo..hi].to_vec(),
        }
    }

    /// Column block as a new CSR.
    pub fn col_block(&self, c: std::ops::Range<usize>) -> Csr {
        assert!(c.end <= self.cols);
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for i in 0..self.rows {
            for (j, v) in self.row_iter(i) {
                if c.contains(&j) {
                    indices.push(j - c.start);
                    values.push(v);
                }
            }
            indptr[i + 1] = indices.len();
        }
        Csr { rows: self.rows, cols: c.len(), indptr, indices, values }
    }

    /// Gather the given columns into a **dense** matrix (subsampling sketch
    /// `M_{I_r:} Sᵗ`: output is |I_r|×d with d small, so dense is right).
    pub fn gather_cols_dense(&self, idx: &[usize]) -> Mat {
        // invert the index list: col → position(s). d ≪ n so a map over all
        // columns is fine and keeps the nonzero scan O(nnz).
        let mut pos = vec![usize::MAX; self.cols];
        for (p, &j) in idx.iter().enumerate() {
            debug_assert!(j < self.cols);
            pos[j] = p;
        }
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let orow = out.row_mut(i);
            for (j, v) in self.row_iter(i) {
                let p = pos[j];
                if p != usize::MAX {
                    orow[p] = v;
                }
            }
        }
        out
    }

    /// Materialised transpose (CSC view as CSR).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &j in &self.indices {
            counts[j + 1] += 1;
        }
        for j in 1..=self.cols {
            counts[j] += counts[j - 1];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0usize; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        for i in 0..self.rows {
            for (j, v) in self.row_iter(i) {
                let p = cursor[j];
                indices[p] = i;
                values[p] = v;
                cursor[j] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// Dense copy (tests only).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let orow = out.row_mut(i);
            for (j, v) in self.row_iter(i) {
                orow[j] += v;
            }
        }
        out
    }

    /// `out = self · dense` (m×n · n×k → m×k), parallel over row ranges.
    pub fn spmm(&self, dense: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, dense.cols());
        self.spmm_into(dense, &mut out);
        out
    }

    /// [`Csr::spmm`] into caller-owned scratch (resized in place) — the
    /// zero-alloc path used by the iteration workspaces.
    pub fn spmm_into(&self, dense: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, dense.rows(), "spmm shape mismatch");
        let k = dense.cols();
        out.resize_to(self.rows, k);
        if k == 0 || self.rows == 0 {
            return;
        }
        let indptr = &self.indptr;
        let indices = &self.indices;
        let values = &self.values;
        let d_data = dense.data();
        parallel::par_chunks_mut(out.data_mut(), 64 * k, |chunk_idx, c_chunk| {
            c_chunk.fill(0.0); // scratch may carry a previous iteration
            let i0 = chunk_idx * 64;
            let rows_here = c_chunk.len() / k;
            for li in 0..rows_here {
                let i = i0 + li;
                let c_row = &mut c_chunk[li * k..(li + 1) * k];
                for p in indptr[i]..indptr[i + 1] {
                    let (j, v) = (indices[p], values[p]);
                    gemm::saxpy(v, &d_data[j * k..(j + 1) * k], c_row);
                }
            }
        });
    }

    /// `out = selfᵀ · dense` (n×m ᵀ·… wait: self m×n, dense m×k → n×k),
    /// computed without materialising the transpose, via thread-local
    /// accumulators over row ranges.
    pub fn spmm_tn(&self, dense: &Mat) -> Mat {
        assert_eq!(self.rows, dense.rows(), "spmm_tn shape mismatch");
        let k = dense.cols();
        let n = self.cols;
        let nparts = parallel::num_threads().min(self.rows.div_ceil(256)).max(1);
        let d_data = dense.data();
        let partials = parallel::par_map(nparts, |p| {
            let ranges = parallel::split_ranges(self.rows, nparts);
            let mut part = vec![0.0f32; n * k];
            for i in ranges[p].clone() {
                let d_row = &d_data[i * k..(i + 1) * k];
                for (j, v) in self.row_iter(i) {
                    gemm::saxpy(v, d_row, &mut part[j * k..(j + 1) * k]);
                }
            }
            part
        });
        let mut out = Mat::zeros(n, k);
        let out_data = out.data_mut();
        for part in partials {
            for (o, pv) in out_data.iter_mut().zip(part.iter()) {
                *o += pv;
            }
        }
        out
    }

    /// `⟨M, U·Vᵀ⟩` over the nonzeros of `M` only — the key primitive for the
    /// sparse-efficient Frobenius loss:
    /// `‖M−UVᵀ‖² = ‖M‖² − 2⟨M,UVᵀ⟩ + ⟨UᵀU, VᵀV⟩`.
    pub fn dot_with_uv(&self, u: &Mat, v: &Mat) -> f64 {
        assert_eq!(u.rows(), self.rows);
        assert_eq!(v.rows(), self.cols);
        assert_eq!(u.cols(), v.cols());
        let k = u.cols();
        let nparts = parallel::num_threads().min(self.rows.div_ceil(512)).max(1);
        let sums = parallel::par_map(nparts, |p| {
            let ranges = parallel::split_ranges(self.rows, nparts);
            let mut s = 0.0f64;
            for i in ranges[p].clone() {
                let u_row = &u.data()[i * k..(i + 1) * k];
                for (j, mv) in self.row_iter(i) {
                    let v_row = &v.data()[j * k..(j + 1) * k];
                    s += mv as f64 * gemm::dot(u_row, v_row) as f64;
                }
            }
            s
        });
        sums.into_iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_sparse(rows: usize, cols: usize, nnz: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed as u128, 0);
        let t: Vec<(usize, usize, f32)> = (0..nnz)
            .map(|_| (rng.below(rows), rng.below(cols), rng.next_f32() + 0.1))
            .collect();
        Csr::from_triplets(rows, cols, t)
    }

    #[test]
    fn triplets_roundtrip() {
        let c = Csr::from_triplets(3, 4, vec![(0, 1, 2.0), (2, 3, 4.0), (0, 1, 1.0), (1, 0, 5.0)]);
        let d = c.to_dense();
        assert_eq!(d.get(0, 1), 3.0, "duplicates summed");
        assert_eq!(d.get(2, 3), 4.0);
        assert_eq!(d.get(1, 0), 5.0);
        assert_eq!(c.nnz(), 3);
    }

    #[test]
    fn dense_roundtrip() {
        let m = Mat::from_rows(&[&[0.0, 1.0, 0.0], &[2.0, 0.0, 3.0]]);
        let c = Csr::from_dense(&m, 0.0);
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.to_dense().data(), m.data());
    }

    #[test]
    fn transpose_correct() {
        let c = random_sparse(13, 29, 60, 3);
        let t = c.transpose();
        assert_eq!(t.rows(), 29);
        let d = c.to_dense();
        let td = t.to_dense();
        for i in 0..13 {
            for j in 0..29 {
                assert_eq!(d.get(i, j), td.get(j, i));
            }
        }
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Pcg64::new(9, 0);
        let c = random_sparse(40, 25, 120, 7);
        let x = Mat::rand_uniform(25, 6, 1.0, &mut rng);
        let got = c.spmm(&x);
        let expect = c.to_dense().matmul(&x);
        for (a, b) in got.data().iter().zip(expect.data().iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn spmm_tn_matches_dense() {
        let mut rng = Pcg64::new(10, 0);
        let c = random_sparse(40, 25, 120, 8);
        let x = Mat::rand_uniform(40, 6, 1.0, &mut rng);
        let got = c.spmm_tn(&x);
        let expect = c.to_dense().transpose().matmul(&x);
        for (a, b) in got.data().iter().zip(expect.data().iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn gather_cols_matches_dense() {
        let c = random_sparse(20, 30, 100, 11);
        let idx = vec![3usize, 29, 0, 7];
        let got = c.gather_cols_dense(&idx);
        let expect = c.to_dense().gather_cols(&idx);
        assert_eq!(got.data(), expect.data());
    }

    #[test]
    fn blocks_match_dense() {
        let c = random_sparse(20, 30, 100, 12);
        let d = c.to_dense();
        assert_eq!(c.row_block(5..12).to_dense().data(), d.row_block(5..12).data());
        assert_eq!(c.col_block(10..25).to_dense().data(), d.col_block(10..25).data());
    }

    #[test]
    fn dot_with_uv_matches_dense() {
        let mut rng = Pcg64::new(13, 0);
        let c = random_sparse(15, 12, 50, 13);
        let u = Mat::rand_uniform(15, 4, 1.0, &mut rng);
        let v = Mat::rand_uniform(12, 4, 1.0, &mut rng);
        let uvt = u.matmul_nt(&v);
        let mut expect = 0.0f64;
        let d = c.to_dense();
        for i in 0..15 {
            for j in 0..12 {
                expect += d.get(i, j) as f64 * uvt.get(i, j) as f64;
            }
        }
        assert!((c.dot_with_uv(&u, &v) - expect).abs() < 1e-3);
    }
}
