//! Row-major dense f32 matrix.

use super::gemm;
use crate::rng::{Gaussian, Pcg64};

/// Row-major dense matrix of `f32`.
///
/// Row-major is the natural layout for the paper's algorithms: both factor
/// matrices are partitioned and updated **by rows** (`U_{I_r:}`, `V_{J_r:}`),
/// and the NLS subproblems are row-independent (Eq. 5).
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from an owned row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Mat { rows, cols, data }
    }

    /// Matrix from row slices (tests / small literals).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Build from a function of (i, j).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    /// Uniform[0, scale) random matrix (NMF factor initialisation).
    pub fn rand_uniform(rows: usize, cols: usize, scale: f32, rng: &mut Pcg64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for x in m.data.iter_mut() {
            *x = rng.next_f32() * scale;
        }
        m
    }

    /// N(0, sigma²) random matrix.
    pub fn rand_gaussian(rows: usize, cols: usize, sigma: f32, rng: Pcg64) -> Self {
        let mut g = Gaussian::new(rng);
        let mut m = Mat::zeros(rows, cols);
        g.fill(&mut m.data, sigma);
        m
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place to `rows × cols`, reusing the existing buffer.
    /// Grows (allocating) only when the element count increases — the
    /// workspace-reuse primitive behind the zero-alloc iteration path.
    /// Contents are unspecified afterwards; callers overwrite.
    pub fn resize_to(&mut self, rows: usize, cols: usize) {
        let need = rows * cols;
        if self.data.len() != need {
            self.data.resize(need, 0.0);
        }
        self.rows = rows;
        self.cols = cols;
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Copy of rows `r` as a new matrix.
    pub fn row_block(&self, r: std::ops::Range<usize>) -> Mat {
        assert!(r.end <= self.rows);
        Mat {
            rows: r.len(),
            cols: self.cols,
            data: self.data[r.start * self.cols..r.end * self.cols].to_vec(),
        }
    }

    /// Copy of columns `c` as a new matrix.
    pub fn col_block(&self, c: std::ops::Range<usize>) -> Mat {
        assert!(c.end <= self.cols);
        let mut out = Mat::zeros(self.rows, c.len());
        for i in 0..self.rows {
            let src = &self.data[i * self.cols + c.start..i * self.cols + c.end];
            out.row_mut(i).copy_from_slice(src);
        }
        out
    }

    /// Gather the given columns into a new matrix (subsampling sketch apply).
    pub fn gather_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let row = self.row(i);
            let orow = out.row_mut(i);
            for (p, &j) in idx.iter().enumerate() {
                orow[p] = row[j];
            }
        }
        out
    }

    /// Materialised transpose.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// `self · other` (m×k · k×n).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        gemm::gemm_nn(self, other, &mut out);
        out
    }

    /// `self · otherᵀ` (m×k · n×k ᵀ).
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let mut out = Mat::zeros(self.rows, other.rows);
        gemm::gemm_nt(self, other, &mut out);
        out
    }

    /// `selfᵀ · other` (k×m ᵀ · m×n).
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let mut out = Mat::zeros(self.cols, other.cols);
        gemm::gemm_tn(self, other, &mut out);
        out
    }

    /// Gram matrix `selfᵀ · self` (k×k for an m×k factor).
    pub fn gram(&self) -> Mat {
        self.matmul_tn(self)
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn fro_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.fro_sq().sqrt()
    }

    /// `self ← self + alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// `self ← alpha * self`.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Element-wise max with a scalar, in place (projection onto R₊).
    pub fn clamp_min(&mut self, floor: f32) {
        for a in self.data.iter_mut() {
            if *a < floor {
                *a = floor;
            }
        }
    }

    /// Element-wise min with a scalar, in place (the paper's Eq. 22 box
    /// constraint that enforces Assumption 2).
    pub fn clamp_max(&mut self, ceil: f32) {
        for a in self.data.iter_mut() {
            if *a > ceil {
                *a = ceil;
            }
        }
    }

    /// Squared Frobenius distance to another matrix.
    pub fn dist_sq(&self, other: &Mat) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    /// True iff every entry is ≥ 0 (invariant of every NMF iterate).
    pub fn is_nonnegative(&self) -> bool {
        self.data.iter().all(|&v| v >= 0.0)
    }

    /// True iff any entry is NaN or infinite.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|v| !v.is_finite())
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Vertically stack matrices with equal column counts.
    pub fn vstack(blocks: &[&Mat]) -> Mat {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        let rows: usize = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            assert_eq!(b.cols, cols, "vstack column mismatch");
            data.extend_from_slice(&b.data);
        }
        Mat { rows, cols, data }
    }

    /// Horizontally stack matrices with equal row counts.
    pub fn hstack(blocks: &[&Mat]) -> Mat {
        assert!(!blocks.is_empty());
        let rows = blocks[0].rows;
        let cols: usize = blocks.iter().map(|b| b.cols).sum();
        let mut out = Mat::zeros(rows, cols);
        for i in 0..rows {
            let orow = out.row_mut(i);
            let mut off = 0;
            for b in blocks {
                assert_eq!(b.rows, rows, "hstack row mismatch");
                orow[off..off + b.cols].copy_from_slice(b.row(i));
                off += b.cols;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.get(1, 0), 3.0);
        assert_eq!(a.row(0), &[1.0, 2.0]);
        let t = a.transpose();
        assert_eq!(t.get(0, 1), 3.0);
        assert!((a.fro_sq() - 30.0).abs() < 1e-6);
    }

    #[test]
    fn matmul_small() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
        // NT and TN agree with explicit transposes
        let nt = a.matmul_nt(&b);
        assert_eq!(nt.data(), a.matmul(&b.transpose()).data());
        let tn = a.matmul_tn(&b);
        assert_eq!(tn.data(), a.transpose().matmul(&b).data());
    }

    #[test]
    fn blocks_and_gather() {
        let m = Mat::from_fn(6, 5, |i, j| (i * 5 + j) as f32);
        let rb = m.row_block(2..4);
        assert_eq!(rb.rows(), 2);
        assert_eq!(rb.get(0, 0), 10.0);
        let cb = m.col_block(1..3);
        assert_eq!(cb.cols(), 2);
        assert_eq!(cb.get(0, 0), 1.0);
        let g = m.gather_cols(&[4, 0]);
        assert_eq!(g.get(1, 0), 9.0);
        assert_eq!(g.get(1, 1), 5.0);
    }

    #[test]
    fn stack_roundtrip() {
        let a = Mat::from_rows(&[&[1.0, 2.0]]);
        let b = Mat::from_rows(&[&[3.0, 4.0]]);
        let v = Mat::vstack(&[&a, &b]);
        assert_eq!(v.rows(), 2);
        assert_eq!(v.get(1, 1), 4.0);
        let h = Mat::hstack(&[&a, &b]);
        assert_eq!(h.cols(), 4);
        assert_eq!(h.get(0, 2), 3.0);
    }

    #[test]
    fn clamp_projection() {
        let mut m = Mat::from_rows(&[&[-1.0, 0.5], &[2.0, -0.1]]);
        m.clamp_min(0.0);
        assert!(m.is_nonnegative());
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(1, 0), 2.0);
    }

    #[test]
    fn gram_is_symmetric() {
        let mut rng = Pcg64::new(5, 0);
        let a = Mat::rand_uniform(20, 7, 1.0, &mut rng);
        let g = a.gram();
        for i in 0..7 {
            for j in 0..7 {
                assert!((g.get(i, j) - g.get(j, i)).abs() < 1e-4);
            }
        }
    }
}
