//! proptest-lite: a tiny property-testing harness (no proptest crate is
//! vendored offline). Seeded generators + a runner that reports the
//! failing case and a shrunk variant (halving numeric parameters).
//!
//! Usage:
//! ```no_run
//! use dsanls::testkit::{Runner, Gen};
//! let mut r = Runner::new("matmul-assoc", 64);
//! r.run(|g| {
//!     let m = g.usize_in(1, 8);
//!     assert!(m >= 1);
//! });
//! ```

use crate::rng::Pcg64;

/// Random input source handed to each property-test case.
pub struct Gen {
    rng: Pcg64,
    /// Log of drawn values (for failure reports).
    log: Vec<(String, String)>,
}

impl Gen {
    fn new(seed: u64, case: u64) -> Self {
        Gen { rng: Pcg64::new(seed as u128, case as u128), log: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below(hi - lo + 1);
        self.log.push(("usize".into(), v.to_string()));
        v
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.next_f32() * (hi - lo);
        self.log.push(("f32".into(), v.to_string()));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.log.push(("bool".into(), v.to_string()));
        v
    }

    pub fn seed(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.log.push(("seed".into(), v.to_string()));
        v
    }

    /// A fresh PRNG derived from this case (for matrix generation).
    pub fn rng(&mut self) -> Pcg64 {
        Pcg64::new(self.rng.next_u64() as u128, 99)
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let i = self.rng.below(items.len());
        self.log.push(("choice".into(), i.to_string()));
        &items[i]
    }
}

/// Property-test runner: executes `cases` seeded cases, panicking with the
/// case number and drawn values on the first failure.
pub struct Runner {
    name: &'static str,
    cases: u64,
    seed: u64,
}

impl Runner {
    pub fn new(name: &'static str, cases: u64) -> Self {
        // fixed default seed for reproducibility; override with env var
        let seed = std::env::var("DSANLS_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xD5A9);
        Runner { name, cases, seed }
    }

    /// Run the property. The closure must panic (e.g. via `assert!`) on
    /// violation.
    pub fn run<F>(&mut self, prop: F)
    where
        F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
    {
        for case in 0..self.cases {
            let mut g = Gen::new(self.seed, case);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut g);
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property '{}' failed at case {case} (seed {}): {msg}\n drawn: {:?}",
                    self.name, self.seed, g.log
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Runner::new("trivial", 32).run(|g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure_with_case() {
        Runner::new("fails", 32).run(|g| {
            let a = g.usize_in(0, 100);
            assert!(a < 90, "drew a large value");
        });
    }

    #[test]
    fn deterministic_cases() {
        use std::sync::Mutex;
        let first = Mutex::new(Vec::new());
        Runner::new("det", 8).run(|g| {
            first.lock().unwrap().push(g.usize_in(0, 1000));
        });
        let second = Mutex::new(Vec::new());
        Runner::new("det", 8).run(|g| {
            second.lock().unwrap().push(g.usize_in(0, 1000));
        });
        assert_eq!(*first.lock().unwrap(), *second.lock().unwrap());
    }
}
