//! Shared little-endian binary-IO helpers for the crate's on-disk formats.
//!
//! Checkpoints (`nmf::control`) and shard directories (`data::shard`) use
//! the same primitive encodings — LE scalars, bulk `f32`/`u64` payloads
//! decoded with one `read_exact` per array — but must keep their historical
//! error wording ("truncated checkpoint …" vs "truncated shard file …").
//! [`BinFormat`] carries the two nouns so one implementation serves both
//! formats without changing a single diagnostic string.

use std::io::{Read, Write};

use crate::error::{Context, Result};

/// Error-message framing for one on-disk format family.
///
/// `noun` names the format in write contexts ("writing {noun} u64");
/// `truncated` names it in short-read contexts ("truncated {truncated}
/// (reading {what})").
#[derive(Clone, Copy)]
pub struct BinFormat {
    /// Noun used in write-error contexts.
    pub noun: &'static str,
    /// Noun used in truncation (short-read) contexts.
    pub truncated: &'static str,
}

/// Framing for checkpoint files ("truncated checkpoint (reading …)").
pub const CHECKPOINT: BinFormat = BinFormat { noun: "checkpoint", truncated: "checkpoint" };

/// Framing for shard manifests/blocks ("truncated shard file (reading …)").
pub const SHARD: BinFormat = BinFormat { noun: "shard", truncated: "shard file" };

/// Framing for compressed shard manifests/blocks (`data::compress`,
/// `dsanls shard --compress`): "truncated compressed shard file (reading …)".
pub const COMPRESSED: BinFormat =
    BinFormat { noun: "compressed shard", truncated: "compressed shard file" };

impl BinFormat {
    /// Write one `u64`, little-endian.
    pub fn write_u64<W: Write>(self, w: &mut W, v: u64) -> Result<()> {
        w.write_all(&v.to_le_bytes()).with_context(|| format!("writing {} u64", self.noun))
    }

    /// Write one `u32`, little-endian.
    pub fn write_u32<W: Write>(self, w: &mut W, v: u32) -> Result<()> {
        w.write_all(&v.to_le_bytes()).with_context(|| format!("writing {} u32", self.noun))
    }

    /// Write one `f64` as its LE bit pattern.
    pub fn write_f64<W: Write>(self, w: &mut W, v: f64) -> Result<()> {
        w.write_all(&v.to_bits().to_le_bytes())
            .with_context(|| format!("writing {} f64", self.noun))
    }

    /// Write a slice of `f32`s, little-endian, element by element.
    pub fn write_f32s<W: Write>(self, w: &mut W, vs: &[f32]) -> Result<()> {
        for &v in vs {
            w.write_all(&v.to_le_bytes())
                .with_context(|| format!("writing {} f32 payload", self.noun))?;
        }
        Ok(())
    }

    /// Write a slice of `usize`s as LE `u64`s.
    pub fn write_u64s<W: Write>(self, w: &mut W, vs: &[usize]) -> Result<()> {
        for &v in vs {
            self.write_u64(w, v as u64)?;
        }
        Ok(())
    }

    /// `read_exact` with a "truncated … (reading {what})" diagnostic.
    pub fn read_exact<R: Read>(self, r: &mut R, buf: &mut [u8], what: &str) -> Result<()> {
        r.read_exact(buf)
            .with_context(|| format!("truncated {} (reading {what})", self.truncated))
    }

    /// Read one LE `u64`.
    pub fn read_u64<R: Read>(self, r: &mut R, what: &str) -> Result<u64> {
        let mut b = [0u8; 8];
        self.read_exact(r, &mut b, what)?;
        Ok(u64::from_le_bytes(b))
    }

    /// Read one LE `u32`.
    pub fn read_u32<R: Read>(self, r: &mut R, what: &str) -> Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(r, &mut b, what)?;
        Ok(u32::from_le_bytes(b))
    }

    /// Read one `f64` from its LE bit pattern.
    pub fn read_f64<R: Read>(self, r: &mut R, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.read_u64(r, what)?))
    }

    /// Bulk `f32` payload read: one `read_exact` for the whole array (then
    /// an in-place byte→value pass), not one syscall-sized call per
    /// element — block files exist for RCV1-scale inputs where tens of
    /// millions of values are normal.
    pub fn read_f32s<R: Read>(self, r: &mut R, n: usize, what: &str) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        self.read_exact(r, &mut bytes, what)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    /// Bulk `usize` payload read (stored as LE `u64`s); same one-syscall
    /// discipline as [`BinFormat::read_f32s`].
    pub fn read_u64s<R: Read>(self, r: &mut R, n: usize, what: &str) -> Result<Vec<usize>> {
        let mut bytes = vec![0u8; n * 8];
        self.read_exact(r, &mut bytes, what)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(8) {
            out.push(u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as usize);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn scalar_and_bulk_roundtrip() {
        let mut buf = Vec::new();
        CHECKPOINT.write_u64(&mut buf, 0xDEAD_BEEF_0042).unwrap();
        CHECKPOINT.write_u32(&mut buf, 7).unwrap();
        SHARD.write_f64(&mut buf, -0.5).unwrap();
        SHARD.write_f32s(&mut buf, &[1.5, -2.25, 0.0]).unwrap();
        SHARD.write_u64s(&mut buf, &[3, 0, usize::MAX >> 1]).unwrap();

        let mut r = Cursor::new(buf);
        assert_eq!(CHECKPOINT.read_u64(&mut r, "a").unwrap(), 0xDEAD_BEEF_0042);
        assert_eq!(CHECKPOINT.read_u32(&mut r, "b").unwrap(), 7);
        assert_eq!(SHARD.read_f64(&mut r, "c").unwrap(), -0.5);
        assert_eq!(SHARD.read_f32s(&mut r, 3, "d").unwrap(), vec![1.5, -2.25, 0.0]);
        assert_eq!(SHARD.read_u64s(&mut r, 3, "e").unwrap(), vec![3, 0, usize::MAX >> 1]);
    }

    #[test]
    fn truncation_messages_name_the_format() {
        let mut r = Cursor::new(vec![0u8; 3]);
        let err = CHECKPOINT.read_u64(&mut r, "seed").unwrap_err().to_string();
        assert!(err.contains("truncated checkpoint (reading seed)"), "{err}");
        let mut r = Cursor::new(vec![0u8; 3]);
        let err = SHARD.read_u32(&mut r, "format version").unwrap_err().to_string();
        assert!(err.contains("truncated shard file (reading format version)"), "{err}");
    }
}
