//! A small TOML subset parser: `[section]` headers, `key = value` pairs,
//! `#` comments. Values stay as raw strings; typing happens in the typed
//! config layer. (No external TOML crate is vendored in this environment.)

/// A parsed document: ordered `(section, key, value)` triples.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    entries: Vec<(String, String, String)>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            if section.is_empty() {
                return Err(format!("line {}: key outside any [section]", lineno + 1));
            }
            doc.entries.push((section.clone(), key.to_string(), value.trim().to_string()));
        }
        Ok(doc)
    }

    /// Ordered `(section, key, raw-value)` triples.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &str)> {
        self.entries.iter().map(|(s, k, v)| (s.as_str(), k.as_str(), v.as_str()))
    }

    /// Lookup `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v.as_str())
    }
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let doc = TomlDoc::parse(
            "# top comment\n[a]\nx = 1 # trailing\ny = \"str # not comment\"\n[b]\nz = true\n",
        )
        .unwrap();
        assert_eq!(doc.get("a", "x"), Some("1"));
        assert_eq!(doc.get("a", "y"), Some("\"str # not comment\""));
        assert_eq!(doc.get("b", "z"), Some("true"));
        assert_eq!(doc.get("a", "z"), None);
    }

    #[test]
    fn errors() {
        assert!(TomlDoc::parse("x = 1").is_err(), "key outside section");
        assert!(TomlDoc::parse("[a\nx = 1").is_err(), "unterminated section");
        assert!(TomlDoc::parse("[a]\nnope").is_err(), "missing =");
    }
}
