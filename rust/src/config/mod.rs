//! Configuration system: a TOML-lite file format, a typed experiment
//! config with defaults, and `--key=value` CLI overrides.

mod toml_lite;

pub use toml_lite::TomlDoc;

use crate::dist::CommModel;
use crate::nmf::MuSchedule;
use crate::secure::SecureAlgo;
use crate::sketch::SketchKind;
use crate::solvers::SolverKind;
use crate::transport::wire::Precision;

/// Which algorithm family an experiment runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// DSANLS (subsampling or gaussian per `sketch.kind`).
    Dsanls,
    /// MPI-FAUN baseline with the given solver.
    Baseline(SolverKind),
    /// One of the secure protocols.
    Secure(SecureAlgo),
}

impl std::str::FromStr for Algorithm {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        let l = s.to_ascii_lowercase().replace('_', "-");
        match l.as_str() {
            "dsanls" | "dsanls-s" | "dsanls-g" => Ok(Algorithm::Dsanls),
            "mu" | "mpi-faun-mu" => Ok(Algorithm::Baseline(SolverKind::Mu)),
            "hals" | "mpi-faun-hals" => Ok(Algorithm::Baseline(SolverKind::Hals)),
            "anls-bpp" | "bpp" | "abpp" | "mpi-faun-abpp" => {
                Ok(Algorithm::Baseline(SolverKind::AnlsBpp))
            }
            other => other.parse::<SecureAlgo>().map(Algorithm::Secure),
        }
    }
}

impl Algorithm {
    pub fn name(&self) -> String {
        match self {
            Algorithm::Dsanls => "DSANLS".into(),
            Algorithm::Baseline(s) => format!("MPI-FAUN-{}", s.name().to_uppercase()),
            Algorithm::Secure(a) => a.name().into(),
        }
    }
}

/// Fully-resolved experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub algorithm: Algorithm,
    pub dataset: String,
    /// Dataset scale factor (1.0 = the repo's scaled-down Table-1 sizes).
    pub scale: f64,
    pub nodes: usize,
    pub rank: usize,
    pub iterations: usize,
    pub seed: u64,
    pub eval_every: usize,

    pub sketch: SketchKind,
    pub d_u: usize,
    pub d_v: usize,

    pub solver: SolverKind,
    pub mu: MuSchedule,

    /// Secure-protocol knobs.
    pub t1: usize,
    pub t2: usize,
    /// Column-skew for the imbalanced-workload experiments (0 = uniform).
    pub skew: f64,
    pub rounds: usize,
    pub local_iters: usize,

    pub comm: CommModel,
    /// Overlap collective wire time with the next factor-independent GEMM
    /// (`network.overlap`; bit-identical, off by default).
    pub overlap_comm: bool,
    /// Wire precision for collective factor payloads (`network.precision`:
    /// `f32` | `fp16` | `bf16`).
    pub wire_precision: Precision,
    /// TCP transport bootstrap timeout in seconds (`dsanls launch`/`worker`;
    /// data-plane receives allow 4× this).
    pub net_timeout_s: f64,
    pub output_dir: String,
    /// Use the AOT/PJRT local-solver backend where shapes allow.
    pub backend_pjrt: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            algorithm: Algorithm::Dsanls,
            dataset: "MNIST".into(),
            scale: 0.1,
            nodes: 10,
            rank: 100,
            iterations: 100,
            seed: 42,
            eval_every: 5,
            sketch: SketchKind::Subsample,
            d_u: 0,
            d_v: 0,
            solver: SolverKind::ProximalCd,
            mu: MuSchedule::default(),
            t1: 20,
            t2: 5,
            skew: 0.0,
            rounds: 20,
            local_iters: 5,
            comm: CommModel::default(),
            overlap_comm: false,
            wire_precision: Precision::F32,
            net_timeout_s: 30.0,
            output_dir: "results".into(),
            backend_pjrt: false,
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML-lite text; unknown keys are an error (typo guard).
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        for (section, key, value) in doc.entries() {
            cfg.apply(&format!("{section}.{key}"), value)?;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_toml(&text)
    }

    /// Apply one dotted-key override (also used for CLI `--key=value`).
    pub fn apply(&mut self, dotted: &str, value: &str) -> Result<(), String> {
        let v = value.trim().trim_matches('"');
        let parse_usize =
            |v: &str| v.parse::<usize>().map_err(|e| format!("{dotted}: {e}"));
        let parse_f64 = |v: &str| v.parse::<f64>().map_err(|e| format!("{dotted}: {e}"));
        match dotted {
            "experiment.name" => self.name = v.into(),
            "experiment.algorithm" => self.algorithm = v.parse()?,
            "experiment.dataset" => self.dataset = v.to_uppercase(),
            "experiment.scale" => self.scale = parse_f64(v)?,
            "experiment.nodes" => self.nodes = parse_usize(v)?,
            "experiment.rank" => self.rank = parse_usize(v)?,
            "experiment.iterations" => self.iterations = parse_usize(v)?,
            "experiment.seed" => self.seed = parse_usize(v)? as u64,
            "experiment.eval_every" => self.eval_every = parse_usize(v)?,
            "experiment.backend" => {
                self.backend_pjrt = match v {
                    "native" => false,
                    "pjrt" => true,
                    other => return Err(format!("experiment.backend: {other}")),
                }
            }
            "sketch.kind" => self.sketch = v.parse()?,
            "sketch.d_u" => self.d_u = parse_usize(v)?,
            "sketch.d_v" => self.d_v = parse_usize(v)?,
            "solver.kind" => self.solver = v.parse()?,
            "solver.alpha" => self.mu.alpha = parse_f64(v)? as f32,
            "solver.beta" => self.mu.beta = parse_f64(v)? as f32,
            "secure.t1" => self.t1 = parse_usize(v)?,
            "secure.t2" => self.t2 = parse_usize(v)?,
            "secure.skew" => self.skew = parse_f64(v)?,
            "secure.rounds" => self.rounds = parse_usize(v)?,
            "secure.local_iters" => self.local_iters = parse_usize(v)?,
            "network.latency_us" => self.comm.latency = parse_f64(v)? * 1e-6,
            "network.bandwidth_gbps" => self.comm.bandwidth = parse_f64(v)? * 125e6,
            "network.overlap" => {
                self.overlap_comm = v
                    .parse::<bool>()
                    .map_err(|_| format!("network.overlap: expected true/false, got {v}"))?
            }
            "network.precision" => {
                self.wire_precision = v.parse::<Precision>().map_err(|e| e.to_string())?
            }
            "network.timeout_s" => self.net_timeout_s = parse_f64(v)?,
            "output.dir" => self.output_dir = v.into(),
            other => return Err(format!("unknown config key: {other}")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Fig. 2 MNIST run
[experiment]
name = "fig2-mnist"
algorithm = "dsanls"
dataset = "mnist"
nodes = 10
rank = 100
iterations = 50

[sketch]
kind = "gaussian"
d_u = 80

[solver]
kind = "rcd"
alpha = 0.1
beta = 10

[network]
latency_us = 100
bandwidth_gbps = 10
"#;

    #[test]
    fn parses_sample() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.name, "fig2-mnist");
        assert_eq!(cfg.dataset, "MNIST");
        assert_eq!(cfg.nodes, 10);
        assert_eq!(cfg.sketch, SketchKind::Gaussian);
        assert_eq!(cfg.d_u, 80);
        assert_eq!(cfg.mu.alpha, 0.1);
        assert_eq!(cfg.mu.beta, 10.0);
        assert!((cfg.comm.latency - 100e-6).abs() < 1e-12);
        assert!((cfg.comm.bandwidth - 1.25e9).abs() < 1.0);
    }

    #[test]
    fn rejects_unknown_key() {
        let bad = "[experiment]\nfoo = 1\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
    }

    #[test]
    fn algorithm_parsing() {
        assert!(matches!("dsanls".parse::<Algorithm>(), Ok(Algorithm::Dsanls)));
        assert!(matches!(
            "anls-bpp".parse::<Algorithm>(),
            Ok(Algorithm::Baseline(SolverKind::AnlsBpp))
        ));
        assert!(matches!(
            "syn-ssd-uv".parse::<Algorithm>(),
            Ok(Algorithm::Secure(SecureAlgo::SynSsdUv))
        ));
        assert!("wat".parse::<Algorithm>().is_err());
    }

    #[test]
    fn cli_override() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply("experiment.rank", "25").unwrap();
        assert_eq!(cfg.rank, 25);
        assert!(cfg.apply("experiment.rank", "x").is_err());
    }

    #[test]
    fn network_overlap_and_precision_keys() {
        let mut cfg = ExperimentConfig::default();
        assert!(!cfg.overlap_comm);
        assert_eq!(cfg.wire_precision, Precision::F32);
        cfg.apply("network.overlap", "true").unwrap();
        cfg.apply("network.precision", "bf16").unwrap();
        assert!(cfg.overlap_comm);
        assert_eq!(cfg.wire_precision, Precision::Bf16);
        assert!(cfg.apply("network.overlap", "maybe").is_err());
        assert!(cfg.apply("network.precision", "int8").is_err());
    }
}
