//! Minimal error handling (the environment vendors no `anyhow`).
//!
//! A string-backed [`Error`], a crate-wide [`Result`] alias, an
//! anyhow-style [`Context`] extension trait for `Result`/`Option`, and the
//! [`crate::bail!`] / [`crate::err!`] macros. Message chains are flattened
//! into the string eagerly (`"context: cause"`), which is all the CLI and
//! runtime loaders need.

use std::fmt;

/// A flattened error message.
pub struct Error(String);

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error(m.into())
    }

    /// Build a typed peer-loss error: `peer` vanished from the collective.
    ///
    /// The in-string marker survives [`Context`] chaining (context is only
    /// ever *prepended*), so layers far from the transport can still ask
    /// [`Error::lost_peer`] whether a failure is a recoverable membership
    /// event rather than a plain fault.
    pub fn peer_lost(peer: usize, detail: impl fmt::Display) -> Self {
        Error(format!("{detail} [peer-lost:{peer}]"))
    }

    /// Like [`Error::peer_lost`], but for "every peer is gone".
    pub fn peer_lost_all(detail: impl fmt::Display) -> Self {
        Error(format!("{detail} [peer-lost:*]"))
    }

    /// Whether this error carries a peer-loss marker (any flavour).
    pub fn is_peer_lost(&self) -> bool {
        self.0.contains("[peer-lost:")
    }

    /// Decode the peer-loss marker, if present.
    ///
    /// Returns `None` for ordinary errors, `Some(Some(r))` when rank `r`
    /// was lost, and `Some(None)` when every peer disconnected at once.
    pub fn lost_peer(&self) -> Option<Option<usize>> {
        let start = self.0.find("[peer-lost:")? + "[peer-lost:".len();
        let rest = &self.0[start..];
        let end = rest.find(']')?;
        match &rest[..end] {
            "*" => Some(None),
            digits => digits.parse::<usize>().ok().map(Some),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Self {
        Error(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Attach context to an error path (anyhow-style).
pub trait Context<T> {
    /// Wrap the error as `"{ctx}: {cause}"` (or use `ctx` alone for `None`).
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Like [`Context::context`] but lazily built.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::fs::read_to_string("/definitely/not/a/path/3141592653");
        e.context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_messages() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().starts_with("reading config: "), "{err}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing field").unwrap_err();
        assert_eq!(err.to_string(), "missing field");
    }

    #[test]
    fn peer_lost_marker_survives_context() {
        let base: Result<()> = Err(Error::peer_lost(3, "peer 3 disconnected"));
        let chained = base.context("all-reduce failed on rank 0").unwrap_err();
        assert!(chained.is_peer_lost(), "{chained}");
        assert_eq!(chained.lost_peer(), Some(Some(3)));

        let all: Result<()> = Err(Error::peer_lost_all("all peers disconnected"));
        let all = all.context("recv").unwrap_err();
        assert_eq!(all.lost_peer(), Some(None));

        let plain = Error::msg("timed out");
        assert!(!plain.is_peer_lost());
        assert_eq!(plain.lost_peer(), None);
    }

    #[test]
    fn bail_and_err_macros() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Err(err!("always fails with {x}"))
        }
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(2).unwrap_err().to_string(), "always fails with 2");
    }
}
