//! `dsanls` — CLI launcher for the DSANLS reproduction.
//!
//! Subcommands:
//! * `run [--config FILE] [--key=value ...]` — run one experiment and
//!   write the trace to `<output.dir>/<name>.csv`.
//! * `launch --nodes N [--config FILE] [--verify-sim] [--bind HOST]
//!   [--hosts FILE] [--shards DIR] ...` — run the same experiment over
//!   **real TCP worker processes** (spawned locally, or started by the
//!   operator across hosts with `--hosts`); the asynchronous protocols
//!   get an extra parameter-server process. `--verify-sim` asserts the
//!   factors are bit-identical to the simulated backend.
//! * `worker --rendezvous HOST:PORT --rank R [--bind IP[:PORT]]
//!   [--shards DIR] ...` — one rank of a `launch` cluster. Builds only
//!   its own row/column blocks of the dataset (shard-local synthesis, or
//!   pre-sliced files via `--shards`) — never the full matrix.
//! * `shard --out DIR [--nodes N] [--input FILE] [--compress] ...` —
//!   pre-slice the configured dataset (or an external COO/`.mtx` matrix
//!   file) into per-rank block files + manifest for multi-host deployment;
//!   `--compress` writes fixed sketched views (~1/R the footprint) that
//!   workers factorize directly (see DEPLOYMENT.md).
//! * `serve --checkpoint FILE [--bind ADDR] [--watch-checkpoint] ...` —
//!   load trained factors from a checkpoint and answer batched top-k /
//!   reconstruction / fold-in queries over TCP; `--watch-checkpoint`
//!   hot-swaps each checkpoint rewrite into the live server with zero
//!   downtime (see DEPLOYMENT.md §Serving).
//! * `route --replicas HOST:PORT,... --bind ADDR` — consistent-hash
//!   router fronting several `serve` replicas behind one address, with
//!   health-checked failover and aggregated stats (see DEPLOYMENT.md
//!   §Replicated serving).
//! * `query --addr ADDR <--users IDS [--top-k N|--reconstruct] |
//!   --fold-in ITEM:RATING,... | --fold-in-item USER:RATING,... |
//!   --stats | --reload>` — smoke-test client for a running `serve`
//!   instance (or a `route` front-end — same protocol).
//! * `compare [--config FILE] [--key=value ...]` — run DSANLS against all
//!   three MPI-FAUN baselines on the configured dataset (a Fig. 2 panel).
//! * `secure [--config FILE] ...` — run all six secure protocols on the
//!   configured dataset (a Fig. 6/7 panel; set `secure.skew` for Fig. 7).
//! * `attack` — demonstrate the Theorem-2/3 sketch-inversion attack.
//! * `artifacts` — report which AOT artifacts are loadable via PJRT.
//! * `datasets` — print the Table-1 dataset inventory.

use std::path::Path;

use dsanls::config::{Algorithm, ExperimentConfig};
use dsanls::coordinator;
use dsanls::linalg::Mat;
use dsanls::metrics::{self, Series};
use dsanls::rng::Pcg64;
use dsanls::secure::SecureAlgo;
use dsanls::sketch::{SketchKind, SketchMatrix};
use dsanls::solvers::SolverKind;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("launch") => cmd_result(coordinator::launch::launch_main(&args[1..])),
        Some("worker") => cmd_result(coordinator::launch::worker_main(&args[1..])),
        Some("shard") => cmd_result(coordinator::shard_cli::shard_main(&args[1..])),
        Some("serve") => cmd_result(coordinator::serve_cli::serve_main(&args[1..])),
        Some("query") => cmd_result(coordinator::serve_cli::query_main(&args[1..])),
        Some("route") => cmd_result(coordinator::route_cli::route_main(&args[1..])),
        Some("compare") => cmd_compare(&args[1..]),
        Some("secure") => cmd_secure(&args[1..]),
        Some("attack") => cmd_attack(),
        Some("artifacts") => cmd_artifacts(),
        Some("datasets") => cmd_datasets(),
        Some("--help" | "-h" | "help") | None => {
            usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand: {other}\n");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    println!(
        "dsanls {} — Fast and Secure Distributed NMF (TKDE 2020 reproduction)\n\n\
         USAGE: dsanls <run|launch|worker|shard|serve|route|query|compare|secure|attack|artifacts|datasets> [--config FILE] [--sec.key=value ...]\n\n\
         launch:  dsanls launch --nodes N [--port P] [--bind HOST] [--hosts FILE] [--shards DIR]\n\
                  [--max-seconds S] [--target-error E] [--checkpoint PATH [--checkpoint-every K]]\n\
                  [--resume PATH] [--retries N] [--elastic [--max-joins N]] [--verify-sim]\n\
                  [--overlap] [--wire-precision f32|fp16|bf16] [--config FILE] [--key=value ...]\n\
                  runs the experiment over real TCP worker processes (spawned locally, or\n\
                  started per host by the operator with --hosts — see DEPLOYMENT.md);\n\
                  stop policies end the run early (deadline / convergence), --checkpoint\n\
                  snapshots factors so --resume (or a --retries restart after a rank\n\
                  failure) continues to bit-identical results;\n\
                  --elastic keeps the survivors alive when a rank dies: the coordinator\n\
                  respawns it as `worker --join`, the mesh rebuilds a membership epoch,\n\
                  and the run resumes from the replicated boundary state (retries: 0);\n\
                  --verify-sim re-runs the simulator and asserts bit-identical factors\n\
         worker:  dsanls worker --rendezvous HOST:PORT --rank R [--bind IP[:PORT]]\n\
                  [--advertise HOST[:PORT]] [--shards DIR] [--elastic] [--join]\n\
                  [control flags as for launch] [--config FILE] [--key=value ...]\n\
                  one launch rank; holds only its row/column blocks of the input;\n\
                  --join re-enters a running --elastic cluster as the replacement\n\
                  for a dead rank (operator-driven on multi-host fleets)\n\
         shard:   dsanls shard --out DIR [--nodes N] [--input FILE] [--balance nnz]\n\
                  [--compress [--sketch subgaussian|countsketch] [--ratio R]]\n\
                  [--config FILE] [--key=value ...]\n\
                  pre-slice the dataset — or an external COO/.mtx matrix file (--input,\n\
                  streamed; the full matrix is never materialised) — into per-rank block\n\
                  files for multi-host runs; --balance nnz cuts columns by stored-value\n\
                  count for the secure protocols on skewed data; --compress writes fixed\n\
                  sketched views at ~1/R the raw footprint (DSANLS/baselines factorize\n\
                  them directly; launch/worker autodetect the format)\n\
         serve:   dsanls serve --checkpoint FILE [--bind HOST:PORT] [--batch-max N]\n\
                  [--batch-wait-us U] [--cache N] [--solver hals|cd|pgd] [--sweeps N]\n\
                  [--threads T] [--expect-algo NAME] [--expect-params HASH]\n\
                  [--watch-checkpoint [--watch-interval-ms MS]]\n\
                  load trained factors from a checkpoint and answer batched top-k /\n\
                  reconstruction / fold-in queries over TCP; --watch-checkpoint hot-swaps\n\
                  each checkpoint rewrite into the live server with zero downtime and no\n\
                  mixed-generation batches (see DEPLOYMENT.md)\n\
         route:   dsanls route <--replicas HOST:PORT,... | --hosts FILE> [--bind HOST:PORT]\n\
                  [--vnodes N] [--timeout-ms MS] [--cooldown-ms MS]\n\
                  consistent-hash router fronting several serve replicas behind one\n\
                  address: keyed queries stick to a stable owner and fail over along the\n\
                  ring, --stats aggregates the fleet, --reload hot-swaps every replica\n\
                  (see DEPLOYMENT.md §Replicated serving)\n\
         query:   dsanls query [--addr HOST:PORT] --users ID[,ID...] [--top-k N]\n\
                  dsanls query [--addr HOST:PORT] --users ID[,ID...] --reconstruct\n\
                  dsanls query [--addr HOST:PORT] --fold-in ITEM:RATING[,...] [--top-k N]\n\
                  dsanls query [--addr HOST:PORT] --fold-in-item USER:RATING[,...] [--top-k N]\n\
                  dsanls query [--addr HOST:PORT] --stats\n\
                  dsanls query [--addr HOST:PORT] --reload\n\
                  smoke-test client for a running serve instance or route front-end;\n\
                  --fold-in embeds a new user against fixed V, --fold-in-item a new item\n\
                  against fixed U; --reload triggers the checkpoint hot-swap\n\n\
         Config keys (TOML sections flattened as --section.key=value):\n\
           experiment: name algorithm dataset scale nodes rank iterations seed eval_every backend\n\
           sketch:     kind d_u d_v\n\
           solver:     kind alpha beta\n\
           secure:     t1 t2 skew rounds local_iters\n\
           network:    latency_us bandwidth_gbps timeout_s overlap precision\n\
           output:     dir",
        dsanls::VERSION
    );
}

/// Parse `--config FILE` plus `--section.key=value` overrides.
fn parse_config(args: &[String]) -> Result<ExperimentConfig, String> {
    coordinator::parse_cli_config(args)
}

/// Map a library `Result` onto a process exit code.
fn cmd_result(r: dsanls::Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_run(args: &[String]) -> i32 {
    let cfg = match parse_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    println!(
        "running {} on {} (scale {}, {} nodes, k={}, {} iters)",
        cfg.algorithm.name(),
        cfg.dataset,
        cfg.scale,
        cfg.nodes,
        cfg.rank,
        cfg.iterations
    );
    let out = coordinator::run_experiment(&cfg);
    println!(
        "final rel-error {:.4}  sec/iter {:.4}  {}",
        out.final_error(),
        out.sec_per_iter,
        metrics::stats_summary(&out.stats)
    );
    let path = Path::new(&cfg.output_dir).join(format!("{}.csv", cfg.name));
    if let Err(e) = metrics::write_series_csv(&path, &[out.series()]) {
        eprintln!("write {path:?}: {e}");
        return 1;
    }
    println!("trace written to {path:?}");
    0
}

fn cmd_compare(args: &[String]) -> i32 {
    let base = match parse_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let m = coordinator::load_dataset(&base);
    println!("dataset {} — {}x{} ({} nnz)", base.dataset, m.rows(), m.cols(), m.nnz());
    let mut series: Vec<Series> = Vec::new();
    // DSANLS/S, DSANLS/G, and the three baselines — the Fig. 2 lineup
    for (algo, sketch) in [
        (Algorithm::Dsanls, Some(SketchKind::Subsample)),
        (Algorithm::Dsanls, Some(SketchKind::Gaussian)),
        (Algorithm::Baseline(SolverKind::Mu), None),
        (Algorithm::Baseline(SolverKind::Hals), None),
        (Algorithm::Baseline(SolverKind::AnlsBpp), None),
    ] {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        if let Some(s) = sketch {
            cfg.sketch = s;
        }
        let out = coordinator::run_on(&cfg, &m);
        println!(
            "  {:<16} err {:.4}  sec/iter {:.4}",
            out.label,
            out.final_error(),
            out.sec_per_iter
        );
        series.push(out.series());
    }
    let path = Path::new(&base.output_dir).join(format!("{}-compare.csv", base.name));
    metrics::write_series_csv(&path, &series).ok();
    metrics::print_series("error over simulated time", &series);
    0
}

fn cmd_secure(args: &[String]) -> i32 {
    let base = match parse_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let m = coordinator::load_dataset(&base);
    println!(
        "secure NMF on {} — {}x{}, skew {}",
        base.dataset,
        m.rows(),
        m.cols(),
        base.skew
    );
    let mut series = Vec::new();
    for algo in SecureAlgo::ALL {
        let mut cfg = base.clone();
        cfg.algorithm = Algorithm::Secure(algo);
        let out = coordinator::run_on(&cfg, &m);
        println!(
            "  {:<12} err {:.4}  sec/iter {:.5}",
            out.label,
            out.final_error(),
            out.sec_per_iter
        );
        series.push(out.series());
    }
    let path = Path::new(&base.output_dir).join(format!("{}-secure.csv", base.name));
    metrics::write_series_csv(&path, &series).ok();
    0
}

fn cmd_attack() -> i32 {
    println!("Theorem 2/3 demo: recovering M from (S, M·S) pairs");
    let mut rng = Pcg64::new(0xA77AC4, 0);
    let m = Mat::rand_uniform(8, 32, 1.0, &mut rng);
    let mut sketches = Vec::new();
    let mut observations = Vec::new();
    for t in 0..5 {
        let mut srng = Pcg64::new(0xBEEF + t as u128, 1);
        let s = SketchMatrix::generate(SketchKind::Gaussian, 32, 8, &mut srng);
        observations.push(s.mul_right_dense(&m));
        sketches.push(s);
        let total_d: usize = sketches.iter().map(|s| s.d()).sum();
        match dsanls::secure::sketch_inversion(&sketches, &observations) {
            Some(rec) => {
                println!(
                    "  after {} sketches (Σd = {total_d} ≥ n = 32): RECOVERED, ‖M̂−M‖² = {:.2e}  ← Theorem 3",
                    t + 1,
                    rec.dist_sq(&m)
                );
            }
            None => {
                println!(
                    "  after {} sketches (Σd = {total_d} < n = 32): cannot recover  ← Theorem 2",
                    t + 1
                );
            }
        }
    }
    println!("conclusion: DSANLS-style MS exchange is only secure for limited iterations —");
    println!("the Syn-*/Asyn-* protocols never transmit M-derived payloads at all.");
    0
}

fn cmd_artifacts() -> i32 {
    match dsanls::runtime::PjrtRuntime::load(&dsanls::runtime::PjrtRuntime::default_dir()) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            for name in rt.names() {
                let spec = rt.spec(name).unwrap();
                println!("  {name}  ({})", spec.file);
            }
            0
        }
        Err(e) => {
            eprintln!("artifacts unavailable: {e}");
            eprintln!("run `make artifacts` first");
            1
        }
    }
}

fn cmd_datasets() -> i32 {
    println!(
        "{:<9} {:>9} {:>7} {:>10} {:>9}   (paper: rows cols sparsity)",
        "name", "rows", "cols", "storage", "rank*"
    );
    for d in dsanls::data::ALL_DATASETS {
        let s = d.spec();
        println!(
            "{:<9} {:>9} {:>7} {:>10} {:>9}   ({} {} {:.2}%)",
            s.name,
            s.rows,
            s.cols,
            if s.dense { "dense" } else { "sparse" },
            s.true_rank,
            s.paper_rows,
            s.paper_cols,
            s.paper_sparsity * 100.0
        );
    }
    0
}
