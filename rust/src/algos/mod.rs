//! Distributed NMF algorithms (general, non-secure setting — paper Sec. 3).
//!
//! * [`dsanls`] — the paper's contribution: Distributed Sketched ANLS
//!   (Alg. 2) with proximal-CD or PGD subproblem solvers.
//! * [`dist_anls`] — the MPI-FAUN-style baselines (MU / HALS / ANLS-BPP):
//!   full factor all-gather each iteration, exact NLS operands.
//!
//! Both are generic over the [`crate::transport::Communicator`] backend:
//! the per-rank node runners ([`dsanls::dsanls_rank`],
//! [`dist_anls::dist_anls_rank`]) take a resolved
//! [`crate::data::shard::NodeInput`] (full matrix or shard-resident
//! blocks) and run unchanged on the simulated cluster
//! ([`crate::dist::run_cluster`]) or on real TCP workers; the rank-ordered
//! collectives make all of them bit-identical. Results carry the assembled
//! factors, the error-over-time trace and per-node communication
//! statistics. The ergonomic front door is [`crate::nmf::job::Job`].

pub mod dist_anls;
pub mod dsanls;

pub use dist_anls::DistAnlsOptions;
pub use dsanls::DsanlsOptions;

use crate::dist::CommStats;
use crate::linalg::Mat;
use crate::nmf::control::StopReason;

/// One sample of the convergence trace.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    pub iteration: usize,
    /// Simulated cluster time (seconds) when the sample was taken.
    pub sim_time: f64,
    /// Relative error ‖M − UVᵀ‖/‖M‖.
    pub rel_error: f64,
}

/// One streamed progress sample, delivered to a job observer the moment
/// rank 0 records it (no waiting for the run to finish): the traced error
/// sample plus a snapshot of rank 0's communication statistics at that
/// instant.
#[derive(Debug, Clone, Copy)]
pub struct ProgressEvent {
    /// Outer iteration the sample was taken at.
    pub iteration: usize,
    /// Virtual cluster seconds at the sample (simulated clock or TCP wall).
    pub sim_time: f64,
    /// Relative error ‖M − UVᵀ‖/‖M‖.
    pub rel_error: f64,
    /// Cumulative communication/compute statistics at the sample: rank 0's
    /// own counters for the synchronous protocols (streamed live), or the
    /// clients' **summed** counters for the asynchronous protocols (whose
    /// merged trace is replayed at assembly).
    pub stats: CommStats,
}

/// Streaming progress callback: invoked on rank 0's thread at every traced
/// sample. Must be `Sync` — the simulated backend runs ranks on scoped
/// threads. Register one with
/// [`crate::nmf::job::JobBuilder::observer`].
pub type ObserverFn = dyn Fn(&ProgressEvent) + Sync;

/// The convergence trace a rank accumulates, with an optional streaming
/// observer attached (rank 0 only). Every rank records the same samples so
/// collective control flow stays identical across ranks; only the points
/// survive into [`NodeOutput::trace`].
pub struct Trace<'a> {
    points: Vec<TracePoint>,
    observer: Option<&'a ObserverFn>,
}

impl<'a> Trace<'a> {
    /// A trace that streams each sample to `observer` (pass `None` on
    /// non-zero ranks).
    pub fn new(observer: Option<&'a ObserverFn>) -> Trace<'a> {
        Trace { points: Vec::new(), observer }
    }

    /// Record one sample, streaming it to the observer first.
    pub fn record(&mut self, point: TracePoint, stats: CommStats) {
        if let Some(obs) = self.observer {
            obs(&ProgressEvent {
                iteration: point.iteration,
                sim_time: point.sim_time,
                rel_error: point.rel_error,
                stats,
            });
        }
        self.points.push(point);
    }

    /// Iteration of the most recent sample, if any.
    pub fn last_iteration(&self) -> Option<usize> {
        self.points.last().map(|p| p.iteration)
    }

    /// Relative error of the most recent sample (NaN if none — also NaN on
    /// non-zero ranks of the full-matrix path, which record NaN samples).
    /// This is what the control plane's target-error stop polls.
    pub fn last_error(&self) -> f64 {
        self.points.last().map_or(f64::NAN, |p| p.rel_error)
    }

    /// Consume into the recorded points.
    pub fn into_points(self) -> Vec<TracePoint> {
        self.points
    }

    /// Drop every sample recorded at an iteration **after** `iteration`.
    /// Elastic recovery rolls a rank back to its last committed boundary;
    /// samples from the replayed tail would otherwise appear twice.
    pub fn truncate_after(&mut self, iteration: usize) {
        self.points.retain(|p| p.iteration <= iteration);
    }
}

/// Result of a distributed factorisation run.
#[derive(Debug, Clone)]
pub struct DistRun {
    pub u: Mat,
    pub v: Mat,
    pub trace: Vec<TracePoint>,
    /// Per-node communication/compute statistics (rank-ordered).
    pub stats: Vec<CommStats>,
    /// Simulated seconds per iteration (total cluster time / iterations).
    pub sec_per_iter: f64,
}

impl DistRun {
    pub fn final_error(&self) -> f64 {
        self.trace.last().map(|t| t.rel_error).unwrap_or(f64::NAN)
    }

    pub fn total_bytes_sent(&self) -> usize {
        self.stats.iter().map(|s| s.bytes_sent).sum()
    }
}

/// Rebuild a full factor matrix from rank-ordered flattened blocks
/// (public entry point for sibling modules and integration tests).
pub fn assemble_blocks_pub(blocks: &[Vec<f32>], k: usize) -> Mat {
    assemble_blocks(blocks, k)
}

/// Rebuild a full factor matrix from rank-ordered flattened blocks.
pub(crate) fn assemble_blocks(blocks: &[Vec<f32>], k: usize) -> Mat {
    let rows: usize = blocks.iter().map(|b| b.len() / k).sum();
    let mut data = Vec::with_capacity(rows * k);
    for b in blocks {
        debug_assert_eq!(b.len() % k, 0);
        data.extend_from_slice(b);
    }
    Mat::from_vec(rows, k, data)
}

/// Per-node return value from one cluster rank. Drivers — the in-process
/// [`crate::dist::run_cluster`] / [`crate::dist::run_tcp_cluster`] scopes
/// and the multi-process `dsanls launch` coordinator — collect one per rank
/// and reduce them into a [`DistRun`] via [`reduce_outputs`].
pub struct NodeOutput {
    pub u_block: Mat,
    pub v_block: Mat,
    /// Non-empty only on rank 0.
    pub trace: Vec<TracePoint>,
    pub stats: CommStats,
    pub final_clock: f64,
    /// Why this rank's loop ended (collectively agreed, so identical on
    /// every rank of a synchronous run).
    pub stop: StopReason,
    /// Membership epoch count this rank finished at (1 = the founding
    /// membership; >1 means the mesh was rebuilt around a re-joined rank).
    pub epochs: usize,
}

/// Completed-iteration span of a rank-0 trace (last minus first sample
/// iteration) — the correct `sec_per_iter` divisor when a stop policy
/// ended the run before its budget, or when a resumed run's clock covers
/// only the tail. Falls back to `budget` when the trace has no span
/// (empty, or a single sample from a run stopped before any iteration).
pub fn trace_span(trace: &[TracePoint], budget: usize) -> usize {
    match (trace.first(), trace.last()) {
        (Some(f), Some(l)) if l.iteration > f.iteration => l.iteration - f.iteration,
        _ => budget,
    }
}

/// Assemble rank-ordered [`NodeOutput`]s into a [`DistRun`].
pub fn reduce_outputs(outputs: Vec<NodeOutput>, k: usize, iterations: usize) -> DistRun {
    let u_blocks: Vec<Vec<f32>> = outputs.iter().map(|o| o.u_block.data().to_vec()).collect();
    let v_blocks: Vec<Vec<f32>> = outputs.iter().map(|o| o.v_block.data().to_vec()).collect();
    let u = assemble_blocks(&u_blocks, k);
    let v = assemble_blocks(&v_blocks, k);
    let trace = outputs[0].trace.clone();
    let stats: Vec<CommStats> = outputs.iter().map(|o| o.stats).collect();
    let max_clock = outputs.iter().map(|o| o.final_clock).fold(0.0, f64::max);
    DistRun { u, v, trace, stats, sec_per_iter: max_clock / iterations.max(1) as f64 }
}
