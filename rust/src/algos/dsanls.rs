//! DSANLS — Distributed Sketched ANLS (paper Alg. 2), the core contribution.
//!
//! Per iteration `t`, node `r` (holding row block `M_{I_r:}`, column block
//! `M_{:J_r}`, and factor blocks `U_{I_r:}`, `V_{J_r:}`):
//!
//! 1. regenerates the shared sketch `Sᵗ ∈ R^{n×d}` from the broadcast seed
//!    (zero communication — [`crate::rng::StreamRng`]);
//! 2. computes `A_r = M_{I_r:}·Sᵗ` locally;
//! 3. computes its summand `B̄_r = (V_{J_r:})ᵀ·S_{J_r:}ᵗ` and obtains
//!    `B = Σ B̄_r` via a `k×d` **all-reduce** (Eq. 11) — this is the only
//!    communication, `O(kd)` instead of the baselines' `O(kn)`;
//! 4. updates `U_{I_r:}` with a Theorem-1 solver (proximal CD / PGD) on
//!    `min ‖A_r − U_{I_r:}B‖`;
//! 5. mirrors 1–4 for the V-subproblem with `S'ᵗ ∈ R^{m×d'}`.
//!
//! Because every node derives identical sketches and the all-reduce sums in
//! rank order, the iterates are **bit-identical for any node count** — a
//! property the integration tests assert (`tests/dist_equivalence.rs`).

use super::{NodeOutput, ObserverFn, Trace, TracePoint};
use crate::data::partition::uniform_partition;
use crate::data::shard::NodeInput;
use crate::dist::elastic::{run_step, Elastic};
use crate::dist::{CommModel, NodeCtx};
use crate::linalg::{Mat, Matrix};
use crate::nmf::control::{checkpoint_sync, CheckpointMeta, RunControl, StopReason};
use crate::nmf::{init_factors_from, rel_error, rel_error_parts, MuSchedule};
use crate::rng::{Role, StreamRng};
use crate::sketch::{SketchKind, SketchMatrix};
use crate::solvers::{self, SolverKind, Workspace};
use crate::transport::wire::Precision;
use crate::transport::Communicator;

/// Stable checkpoint algorithm tag for DSANLS runs.
pub const CKPT_TAG: &str = "dsanls";

/// Fingerprint of every result-affecting DSANLS option — what checkpoint
/// resume validates beyond seed/rank/shape (a changed solver or sketch
/// size would replay a *different* trajectory tail). `nodes`, `eval_every`
/// and the comm model are deliberately excluded: node count does not
/// change the iterates (the invariance the paper's design guarantees) and
/// the others never touch the factor math.
pub fn ckpt_params(opts: &DsanlsOptions) -> u64 {
    use crate::nmf::control::{fingerprint_str, params_fingerprint};
    let mut fields = vec![
        fingerprint_str(opts.solver.name()),
        fingerprint_str(opts.sketch.name()),
        opts.d_u as u64,
        opts.d_v as u64,
        opts.mu.alpha.to_bits() as u64,
        opts.mu.beta.to_bits() as u64,
        opts.box_bound as u64,
    ];
    // `overlap` is excluded (bit-identical reordering); a non-default wire
    // precision changes the iterates, so it joins the fingerprint — appended
    // conditionally to keep every pre-existing checkpoint resumable.
    if opts.precision != Precision::F32 {
        fields.push(fingerprint_str(opts.precision.name()));
    }
    params_fingerprint(&fields)
}

/// Options for a DSANLS run.
#[derive(Debug, Clone)]
pub struct DsanlsOptions {
    pub nodes: usize,
    pub rank: usize,
    pub iterations: usize,
    pub solver: SolverKind,
    pub sketch: SketchKind,
    /// Sketch size for the U-subproblem (0 = auto, paper footnote 1).
    pub d_u: usize,
    /// Sketch size for the V-subproblem (0 = auto).
    pub d_v: usize,
    pub seed: u64,
    /// Trace the relative error every this many iterations (0 = end only).
    pub eval_every: usize,
    pub mu: MuSchedule,
    pub comm: CommModel,
    /// Enforce the Eq. 22 box constraint `U,V ≤ √(2‖M‖_F)` after every
    /// update — the explicit way to guarantee Assumption 2 (bounded
    /// iterates); Lemma 1 shows it does not exclude the global optimum.
    pub box_bound: bool,
    /// Overlap each `k×d` reduction with the next factor-independent
    /// sketched GEMM (double-buffered pipeline). Changes only the schedule,
    /// never the iterates — factors stay bit-identical to the blocking path.
    pub overlap: bool,
    /// Wire precision for the collective factor payloads
    /// ([`Precision::F32`] = exact). Reduced precision shrinks bytes ~2× and
    /// perturbs the iterates within the format's relative-error bound.
    pub precision: Precision,
}

impl Default for DsanlsOptions {
    fn default() -> Self {
        DsanlsOptions {
            nodes: 4,
            rank: 10,
            iterations: 100,
            solver: SolverKind::ProximalCd,
            sketch: SketchKind::Subsample,
            d_u: 0,
            d_v: 0,
            seed: 42,
            eval_every: 5,
            mu: MuSchedule::default(),
            comm: CommModel::default(),
            box_bound: false,
            overlap: false,
            precision: Precision::F32,
        }
    }
}

impl DsanlsOptions {
    fn resolve_d(&self, n: usize, m: usize) -> (usize, usize) {
        let auto = |dim: usize| ((dim / 10).max(2 * self.rank)).min(dim).max(1);
        let du = if self.d_u == 0 { auto(n) } else { self.d_u.min(n) };
        let dv = if self.d_v == 0 { auto(m) } else { self.d_v.min(m) };
        (du, dv)
    }
}

/// One DSANLS rank over any transport backend — the single per-rank
/// **node runner** every driver (simulated cluster, in-process TCP, the
/// multi-process `dsanls worker`) funnels through. The rank's view of the
/// input is a resolved [`NodeInput`]: the full matrix (it slices its own
/// blocks) or a shard-resident [`crate::data::shard::NodeData`] whose
/// global `‖M‖²` is already resolved
/// ([`crate::data::shard::exact_fro_sq`] or a shard manifest) — which
/// makes the factor iterates **bit-identical** across the two views.
/// Sharded error traces are evaluated distributively (per-rank row-block
/// residuals, summed), so they may differ from the full path in the last
/// float digits — factors do not.
///
/// Partitions are derived deterministically from the global shape and the
/// cluster size, so every rank agrees without further coordination;
/// `opts.nodes` must match the communicator's cluster size. `observer`
/// (rank 0 only) streams each traced sample as it is recorded.
///
/// `ctl` is the run's control plane: the loop polls the collective stop
/// decision once per iteration (cancel / deadline / target error),
/// snapshots rank-0-assembled factors on the checkpoint cadence, and —
/// when resuming — re-enters the loop at the checkpoint's iteration with
/// the restored factor slices, which replays the exact tail of an
/// uninterrupted run (the RNG streams are derived from `(seed,
/// iteration)`, so the iteration counter is the whole RNG cursor).
///
/// Under `ctl.elastic`, every iteration starts with an untimed boundary
/// commit and runs guarded: a peer loss rolls every rank back to the last
/// committed boundary, the mesh is rebuilt around a replacement
/// ([`crate::dist::elastic`]), and the loop replays from there —
/// bit-identical factors, because the iteration counter is the RNG cursor.
/// `joining = true` marks a replacement rank entering mid-run via the
/// epoch-join handshake: it skips init and every pre-loop collective, and
/// its first act is the recovery exchange that hands it the dead
/// incarnation's committed state.
pub fn dsanls_rank<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    input: NodeInput<'_>,
    opts: &DsanlsOptions,
    observer: Option<&ObserverFn>,
    ctl: &RunControl,
    joining: bool,
) -> NodeOutput {
    assert_eq!(opts.nodes, ctx.nodes(), "opts.nodes must match the cluster size");
    let rank = ctx.rank;
    let (rows, cols) = input.dims();
    let compressed = input.compressed();
    // compressed input fixed the sketch widths at shard time (the resident
    // views *are* the sketched data); raw input resolves them from options
    let (d_u, d_v) = match compressed {
        Some(cb) => (cb.d_c(), cb.d_r()),
        None => opts.resolve_d(cols, rows),
    };
    let row_part = uniform_partition(rows, opts.nodes);
    let col_part = uniform_partition(cols, opts.nodes);
    let stream = StreamRng::new(opts.seed);
    let my_rows = row_part.range(rank);
    let my_cols = col_part.range(rank);
    let mut fro_sq = input.fro_sq();

    // --- data each node is allowed to touch (Fig. 1a partitioning);
    //     compressed input substitutes its fixed sketched views and the raw
    //     blocks are never materialised ---
    let m_rows_buf = compressed.is_none().then(|| input.row_block(my_rows.clone())); // M_{I_r:}
    let m_rows: Option<&Matrix> = m_rows_buf.as_deref();
    let m_cols_t = compressed.is_none().then(|| input.col_block_t(my_cols.clone())); // (M_{:J_r})ᵀ
    if let Some(cb) = compressed {
        assert_eq!(cb.row_range, my_rows, "compressed row range != rank's partition");
        assert_eq!(cb.col_range, my_cols, "compressed col range != rank's partition");
        assert!(!opts.overlap, "overlap × compressed input is rejected at build time");
    }

    // shared-seed init (or checkpoint restore): every node derives the same
    // full factors and keeps its slice ⇒ iterates are independent of the
    // node count. Factor-sized only — never the data matrix.
    let start = ctl.start_iteration();
    let (mut u_block, mut v_block) = if joining {
        // replacement rank: placeholder shapes only — the real state (and
        // the real ‖M‖², carried in the recovery header) arrive through the
        // recovery exchange before the first iteration runs
        (Mat::zeros(my_rows.len(), opts.rank), Mat::zeros(my_cols.len(), opts.rank))
    } else {
        match ctl.resume.as_deref() {
            Some(rs) => (rs.u.row_block(my_rows.clone()), rs.v.row_block(my_cols.clone())),
            None => {
                let (u_full, v_full) = {
                    let mut rng = stream.for_iteration(0, Role::Init);
                    init_factors_from(fro_sq, rows, cols, opts.rank, &mut rng)
                };
                (u_full.row_block(my_rows.clone()), v_full.row_block(my_cols.clone()))
            }
        }
    };

    // Eq. 22 ceiling enforcing Assumption 2 (when requested)
    let mut ceiling = (2.0 * fro_sq.sqrt()).sqrt() as f32;

    let ckpt_meta = CheckpointMeta {
        algo: CKPT_TAG.into(),
        seed: opts.seed,
        k: opts.rank,
        rows,
        cols,
        params: ckpt_params(opts),
    };
    let mut trace = Trace::new(if rank == 0 { observer } else { None });
    // Iteration of the most recent sample, tracked *outside* the trace: the
    // final out-of-band record below must be a collectively agreed decision,
    // and after an elastic recovery the traces themselves diverge (survivors
    // keep pre-fault samples, a joiner starts empty).
    let mut sampled_at = (!joining).then_some(start);
    if !joining {
        record_error_any(
            ctx, &input, m_rows, &u_block, &v_block, fro_sq, opts.rank, start, &mut trace,
        );
    }

    // per-node normal-equation scratch, reused across iterations (zero
    // allocations in the GEMM/solver hot path at steady state)
    let mut ws = Workspace::new();
    let mut stop = StopReason::Completed;
    let mut completed = start;

    // Warm prefetch for the overlapped pipeline: `A_r = M_{I_r:}·Sᵗ` is
    // factor-independent (data × shared-seed sketch), so iteration `start`'s
    // copy is computed up front and every later one rides behind the
    // previous iteration's V-reduction.
    let mut prefetch: Option<SketchMatrix> = None;
    if opts.overlap && start < opts.iterations {
        prefetch = Some(ctx.compute(|| {
            let mut s_rng = stream.for_iteration(start as u64, Role::SketchU);
            let s = SketchMatrix::generate(opts.sketch, cols, d_u, &mut s_rng);
            let mut a = ws.take_pipe(0);
            s.mul_right_into(m_rows.expect("overlap requires raw input"), &mut a);
            ws.restore_pipe(0, a);
            s
        }));
    }

    // elastic membership: iteration-boundary replication + guarded steps
    let mut elastic = ctl.elastic.map(|e| (Elastic::new(), e.min_ranks));
    let elastic_on = elastic.is_some();
    let mut first_join = joining;
    let mut pending_recovery = joining;
    let mut t = start;
    while t < opts.iterations {
        assert!(
            matches!(opts.solver, SolverKind::ProximalCd | SolverKind::Pgd),
            "DSANLS requires a Theorem-1 solver (rcd or pgd)"
        );

        // elastic recovery: a peer was lost mid-iteration (or this rank just
        // joined) — rebuild membership, adopt the committed boundary
        // wholesale, and replay from there
        if pending_recovery {
            let (el, min_ranks) = elastic.as_mut().expect("recovery implies elastic");
            let rec = el
                .recover(ctx, *min_ranks, first_join)
                .unwrap_or_else(|e| panic!("rank {rank} elastic recovery: {e}"));
            first_join = false;
            pending_recovery = false;
            t = rec.iteration;
            fro_sq = rec.fro_sq.0;
            ceiling = (2.0 * fro_sq.sqrt()).sqrt() as f32;
            let u_len = my_rows.len() * opts.rank;
            u_block = Mat::from_vec(my_rows.len(), opts.rank, rec.state[..u_len].to_vec());
            v_block = Mat::from_vec(my_cols.len(), opts.rank, rec.state[u_len..].to_vec());
            trace.truncate_after(t);
            completed = t;
            // every rank — survivor or joiner — resets the sample cursor so
            // the final record decision stays identical across the cluster
            sampled_at = None;
            continue;
        }

        // One guarded iteration: boundary commit, scripted-fault check, stop
        // poll, both subproblems, trace and checkpoint. Under elastic a
        // `PeerLostSignal` unwinding from any collective in here is caught
        // and turned into a boundary recovery; otherwise the step runs bare
        // and panics propagate exactly as before.
        let body = || -> Option<StopReason> {
            if let Some((el, _)) = elastic.as_mut() {
                // commit this rank's factors as they stand at the start of
                // iteration `t` — the state recovery rolls back to
                let mut state =
                    Vec::with_capacity(u_block.data().len() + v_block.data().len());
                state.extend_from_slice(u_block.data());
                state.extend_from_slice(v_block.data());
                el.commit(ctx, t, (fro_sq, 0.0), &state);
            }
            // chaos harness: a scripted kill for (rank, t) unwinds here
            ctx.comm_mut().fault_check(t);

            // collective stop decision — every rank leaves at the same
            // iteration (no pending exchange is ever in flight here: both
            // reductions of an iteration are finished before its
            // trace/checkpoint collectives)
            if let Some(reason) = ctl.poll_sync(ctx, t, trace.last_error()) {
                return Some(reason);
            }

            if let Some(cb) = compressed {
                // ---------- compressed U-subproblem ----------
                // The fixed view `u_view = M_{I_r:}·S_c` replaces the
                // per-iteration `A_r`; the summand `B̄_r = (V_{J_r:})ᵀS_{c,J_r:}`
                // reduces to `B = VᵀS_c` over the same k×d all-reduce as the
                // raw path. Zero per-iteration allocation: the summand lives
                // in the workspace and the view is resident.
                let mut summand = ws.take_summand();
                ctx.compute(|| {
                    cb.s_c().mul_rows_tn_into(&v_block, col_part.offset(rank), &mut summand)
                });
                ctx.all_reduce_sum_q(summand.data_mut(), opts.precision);
                ctx.compute(|| {
                    let nrm = ws.normal_from(cb.u_view(), &summand);
                    solvers::update_auto(opts.solver, &mut u_block, &nrm, &opts.mu, t);
                    if opts.box_bound {
                        u_block.clamp_max(ceiling);
                    }
                });

                // ---------- compressed V-subproblem (mirrored on S_r) ----------
                ctx.compute(|| {
                    cb.s_r().mul_rows_tn_into(&u_block, row_part.offset(rank), &mut summand)
                });
                ctx.all_reduce_sum_q(summand.data_mut(), opts.precision);
                ctx.compute(|| {
                    let nrm = ws.normal_from(cb.v_view(), &summand);
                    solvers::update_auto(opts.solver, &mut v_block, &nrm, &opts.mu, t);
                    if opts.box_bound {
                        v_block.clamp_max(ceiling);
                    }
                });
                ws.restore_summand(summand);
            } else if !opts.overlap {
                // ---------- U-subproblem (Alg. 2 lines 4–8) ----------
                let (a_r, b_sum) = ctx.compute(|| {
                    let mut s_rng = stream.for_iteration(t as u64, Role::SketchU);
                    let s = SketchMatrix::generate(opts.sketch, cols, d_u, &mut s_rng);
                    // M_{I_r:}·Sᵗ, local
                    let a_r = s.mul_right(m_rows.expect("raw input resolves a row block"));
                    let b_bar = s.mul_rows_tn(&v_block, col_part.offset(rank)); // (V_{J_r:})ᵀS_{J_r:}
                    (a_r, b_bar)
                });
                let buf_owned = b_sum;
                let mut buf = buf_owned.into_vec();
                ctx.all_reduce_sum_q(&mut buf, opts.precision); // B = Σ_r B̄_r  (k×d)
                let b = Mat::from_vec(opts.rank, d_u, buf);
                ctx.compute(|| {
                    let nrm = ws.normal_from(&a_r, &b);
                    solvers::update_auto(opts.solver, &mut u_block, &nrm, &opts.mu, t);
                    if opts.box_bound {
                        u_block.clamp_max(ceiling);
                    }
                });

                // ---------- V-subproblem (Alg. 2 lines 10–14) ----------
                let (a2_r, b2_sum) = ctx.compute(|| {
                    let mut s_rng = stream.for_iteration(t as u64, Role::SketchV);
                    let s2 = SketchMatrix::generate(opts.sketch, rows, d_v, &mut s_rng);
                    // (M_{:J_r})ᵀ·S'ᵗ
                    let a2 = s2.mul_right(m_cols_t.as_ref().expect("raw input resolves a col block"));
                    let b2_bar = s2.mul_rows_tn(&u_block, row_part.offset(rank)); // (U_{I_r:})ᵀS'_{I_r:}
                    (a2, b2_bar)
                });
                let buf2_owned = b2_sum;
                let mut buf2 = buf2_owned.into_vec();
                ctx.all_reduce_sum_q(&mut buf2, opts.precision);
                let b2 = Mat::from_vec(opts.rank, d_v, buf2);
                ctx.compute(|| {
                    let nrm = ws.normal_from(&a2_r, &b2);
                    solvers::update_auto(opts.solver, &mut v_block, &nrm, &opts.mu, t);
                    if opts.box_bound {
                        v_block.clamp_max(ceiling);
                    }
                });
            } else {
                // ---------- overlapped double-buffered pipeline ----------
                // Identical arithmetic to the blocking path, reordered so each
                // reduction's wire time hides behind the next factor-independent
                // sketched GEMM. Pipe slot 0 holds A_r, slot 1 holds A'_r; the
                // summand buffer carries B̄_r out and B back. take/restore moves
                // buffers out of the workspace without touching the allocator
                // (an empty `Mat` owns no storage), so `ws.normal_from` can
                // borrow the workspace mutably while the operands stay alive.

                // --- U-subproblem: A_r was prefetched; post B̄_r, then compute
                //     the V-side A'_r = (M_{:J_r})ᵀ·S'ᵗ behind the reduction ---
                let s_u = prefetch.take().expect("warm prefetch precedes the loop");
                let mut summand = ws.take_summand();
                ctx.compute(|| s_u.mul_rows_tn_into(&v_block, col_part.offset(rank), &mut summand));
                let pending = ctx.all_reduce_start(summand.data(), opts.precision);
                let s_v = ctx.compute(|| {
                    let mut s_rng = stream.for_iteration(t as u64, Role::SketchV);
                    let s2 = SketchMatrix::generate(opts.sketch, rows, d_v, &mut s_rng);
                    let mut a2 = ws.take_pipe(1);
                    s2.mul_right_into(m_cols_t.as_ref().expect("overlap requires raw input"), &mut a2);
                    ws.restore_pipe(1, a2);
                    s2
                });
                ctx.all_reduce_finish(pending, summand.data_mut()); // B = Σ_r B̄_r
                let a_r = ws.take_pipe(0);
                ctx.compute(|| {
                    let nrm = ws.normal_from(&a_r, &summand);
                    solvers::update_auto(opts.solver, &mut u_block, &nrm, &opts.mu, t);
                    if opts.box_bound {
                        u_block.clamp_max(ceiling);
                    }
                });
                ws.restore_pipe(0, a_r);

                // --- V-subproblem: post B̄'_r (needs the U just updated), then
                //     prefetch iteration t+1's A_r behind the reduction ---
                ctx.compute(|| s_v.mul_rows_tn_into(&u_block, row_part.offset(rank), &mut summand));
                let pending2 = ctx.all_reduce_start(summand.data(), opts.precision);
                if t + 1 < opts.iterations {
                    prefetch = Some(ctx.compute(|| {
                        let mut s_rng = stream.for_iteration((t + 1) as u64, Role::SketchU);
                        let s = SketchMatrix::generate(opts.sketch, cols, d_u, &mut s_rng);
                        let mut a = ws.take_pipe(0);
                        s.mul_right_into(m_rows.expect("overlap requires raw input"), &mut a);
                        ws.restore_pipe(0, a);
                        s
                    }));
                }
                ctx.all_reduce_finish(pending2, summand.data_mut());
                let a2_r = ws.take_pipe(1);
                ctx.compute(|| {
                    let nrm = ws.normal_from(&a2_r, &summand);
                    solvers::update_auto(opts.solver, &mut v_block, &nrm, &opts.mu, t);
                    if opts.box_bound {
                        v_block.clamp_max(ceiling);
                    }
                });
                ws.restore_pipe(1, a2_r);
                ws.restore_summand(summand);
            }

            completed = t + 1;
            if opts.eval_every > 0 && (t + 1) % opts.eval_every == 0 {
                record_error_any(
                    ctx, &input, m_rows, &u_block, &v_block, fro_sq, opts.rank, t + 1, &mut trace,
                );
                sampled_at = Some(t + 1);
            }
            if ctl.should_checkpoint(t + 1) {
                checkpoint_sync(
                    ctx,
                    ctl.checkpoint.as_ref().expect("cadence implies config"),
                    &ckpt_meta,
                    t + 1,
                    &u_block,
                    &v_block,
                );
            }
            None
        };
        match if elastic_on { run_step(body) } else { Ok(body()) } {
            Ok(Some(reason)) => {
                stop = reason;
                break;
            }
            Ok(None) => t += 1,
            Err(_lost) => pending_recovery = true,
        }
    }
    if sampled_at != Some(completed) {
        record_error_any(
            ctx, &input, m_rows, &u_block, &v_block, fro_sq, opts.rank, completed, &mut trace,
        );
    }

    NodeOutput {
        u_block,
        v_block,
        trace: if rank == 0 { trace.into_points() } else { Vec::new() },
        stats: ctx.stats(),
        final_clock: ctx.clock(),
        stop,
        epochs: elastic.as_ref().map_or(1, |(el, _)| el.rebuilds + 1),
    }
}

/// Out-of-band error evaluation, dispatching on what the rank can see:
/// the full matrix (legacy exact evaluation on rank 0) or only its blocks
/// (distributed row-block residuals). Same signature shape for both so the
/// iteration loop stays single-path. `fro_sq` is the caller's live global
/// `‖M‖²` — passed explicitly (not read off the shard) because an elastic
/// joiner's shard carries NaN until the recovery header supplies the real
/// value.
#[allow(clippy::too_many_arguments)]
pub(crate) fn record_error_any<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    input: &NodeInput<'_>,
    m_rows: Option<&Matrix>,
    u_block: &Mat,
    v_block: &Mat,
    fro_sq: f64,
    k: usize,
    iteration: usize,
    trace: &mut Trace<'_>,
) {
    match input {
        NodeInput::Full(m) => record_error(ctx, m, u_block, v_block, k, iteration, trace),
        NodeInput::Shard(_) => {
            let m_rows = m_rows.expect("sharded input resolves a row block");
            record_error_sharded(ctx, m_rows, u_block, v_block, fro_sq, k, iteration, trace)
        }
        NodeInput::Compressed(cb) => {
            record_error_compressed(ctx, cb, u_block, v_block, k, iteration, trace)
        }
    }
}

/// Out-of-band error evaluation: gather the factor blocks (untimed) and let
/// rank 0 compute the global relative error against the full matrix.
pub(crate) fn record_error<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    m: &Matrix,
    u_block: &Mat,
    v_block: &Mat,
    k: usize,
    iteration: usize,
    trace: &mut Trace<'_>,
) {
    let sim_time = ctx.clock();
    let err = ctx.untimed(|ctx| {
        let u_blocks = ctx.all_gather(u_block.data());
        let v_blocks = ctx.all_gather(v_block.data());
        if ctx.rank == 0 {
            let u = super::assemble_blocks(&u_blocks, k);
            let v = super::assemble_blocks(&v_blocks, k);
            rel_error(m, &u, &v)
        } else {
            f64::NAN
        }
    });
    // Every rank records the sample (non-zero ranks with NaN error) so that
    // trace-based control flow stays identical across ranks — collectives
    // must be entered by everyone or nobody.
    trace.record(TracePoint { iteration, sim_time, rel_error: err }, ctx.stats());
}

/// Sharded out-of-band error: every rank gathers the full `V` factor
/// (factor-sized), evaluates `‖M_{I_r:} − U_{I_r:}Vᵀ‖²` on its resident
/// row block, and the squared residuals are summed with a scalar
/// all-reduce — no rank ever needs the full matrix. Every rank learns the
/// real error (the full path reports NaN off rank 0).
#[allow(clippy::too_many_arguments)]
pub(crate) fn record_error_sharded<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    m_rows: &Matrix,
    u_block: &Mat,
    v_block: &Mat,
    fro_sq: f64,
    k: usize,
    iteration: usize,
    trace: &mut Trace<'_>,
) {
    let sim_time = ctx.clock();
    let err = ctx.untimed(|ctx| {
        let v_blocks = ctx.all_gather(v_block.data());
        let v = super::assemble_blocks(&v_blocks, k);
        let (_, resid) = rel_error_parts(m_rows, u_block, &v);
        let mut buf = [(resid / fro_sq) as f32];
        ctx.all_reduce_sum(&mut buf);
        (buf[0].max(0.0) as f64).sqrt()
    });
    trace.record(TracePoint { iteration, sim_time, rel_error: err }, ctx.stats());
}

/// Compressed out-of-band error: the raw matrix never exists on any rank,
/// so the trace reports a *sketched residual proxy*
/// `‖M·S_c − U·(VᵀS_c)ᵀ‖_F / ‖M·S_c‖_F`, computed entirely from the
/// resident `u_view = M_{I_r:}·S_c` and the gathered `V`. By the
/// Johnson–Lindenstrauss property of the fixed column sketch this tracks
/// the true relative error up to the sketch distortion (see EXPERIMENTS.md
/// "Compressed recovery"). The denominator `‖M·S_c‖²` is the manifest's
/// `sketched_fro_sq` constant, folded in via `NodeInput::fro_sq()` at load.
pub(crate) fn record_error_compressed<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    cb: &crate::data::CompressedBlock,
    u_block: &Mat,
    v_block: &Mat,
    k: usize,
    iteration: usize,
    trace: &mut Trace<'_>,
) {
    let sim_time = ctx.clock();
    let err = ctx.untimed(|ctx| {
        let v_blocks = ctx.all_gather(v_block.data());
        let v = super::assemble_blocks(&v_blocks, k);
        // w = (VᵀS_c)ᵀ = S_cᵀV, shaped d_c×k so `rel_error_parts` sees the
        // sketched row block `u_view` (|I_r|×d_c) against U_{I_r:}·wᵀ.
        let w = cb.s_c().mul_rows_tn(&v, 0).transpose();
        let view = Matrix::Dense(cb.u_view().clone());
        let (_, resid) = rel_error_parts(&view, u_block, &w);
        let mut buf = [(resid / cb.sketched_fro_sq) as f32];
        ctx.all_reduce_sum(&mut buf);
        (buf[0].max(0.0) as f64).sqrt()
    });
    trace.record(TracePoint { iteration, sim_time, rel_error: err }, ctx.stats());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::run_cluster;
    use crate::nmf::job::{Algo, DataSource, Job};
    use crate::rng::Pcg64;

    fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed as u128, 0);
        let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
        let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
        Matrix::Dense(u.matmul_nt(&v))
    }

    /// The builder is the only front door now; this is the module-local
    /// shorthand the old deprecated shim used to provide.
    fn run_dsanls(m: &Matrix, opts: &DsanlsOptions) -> crate::algos::DistRun {
        Job::builder()
            .algorithm(Algo::Dsanls(opts.clone()))
            .data(DataSource::Full(m))
            .run()
            .unwrap_or_else(|e| panic!("DSANLS job failed: {e}"))
            .into_dist_run()
    }

    #[test]
    fn converges_on_low_rank() {
        let m = low_rank(80, 60, 3, 201);
        let run = run_dsanls(
            &m,
            &DsanlsOptions {
                nodes: 3,
                rank: 3,
                iterations: 120,
                d_u: 24,
                d_v: 24,
                eval_every: 20,
                ..Default::default()
            },
        );
        let first = run.trace.first().unwrap().rel_error;
        assert!(
            run.final_error() < 0.5 * first,
            "{} -> {}",
            first,
            run.final_error()
        );
        assert!(run.u.is_nonnegative() && run.v.is_nonnegative());
        assert_eq!(run.u.rows(), 80);
        assert_eq!(run.v.rows(), 60);
    }

    #[test]
    fn node_count_invariance() {
        // Same seed ⇒ identical traces for any N (the shared-sketch design).
        let m = low_rank(60, 48, 3, 203);
        let mk = |nodes| {
            run_dsanls(
                &m,
                &DsanlsOptions {
                    nodes,
                    rank: 3,
                    iterations: 20,
                    d_u: 16,
                    d_v: 16,
                    eval_every: 5,
                    ..Default::default()
                },
            )
        };
        let r2 = mk(2);
        let r4 = mk(4);
        for (a, b) in r2.trace.iter().zip(r4.trace.iter()) {
            assert_eq!(a.iteration, b.iteration);
            assert!(
                (a.rel_error - b.rel_error).abs() < 1e-5,
                "iter {}: {} vs {}",
                a.iteration,
                a.rel_error,
                b.rel_error
            );
        }
    }

    #[test]
    fn communication_is_kd_not_kn() {
        // per-iteration bytes per node ≈ 2 all-reduces of k×d floats —
        // independent of n. Doubling n must not change comm volume.
        let k = 4;
        let d = 16;
        let opts = |_: usize| DsanlsOptions {
            nodes: 2,
            rank: k,
            iterations: 10,
            d_u: d,
            d_v: d,
            eval_every: 0,
            ..Default::default()
        };
        let small = run_dsanls(&low_rank(40, 60, 3, 205), &opts(60));
        let large = run_dsanls(&low_rank(40, 120, 3, 205), &opts(120));
        assert_eq!(
            small.total_bytes_sent(),
            large.total_bytes_sent(),
            "comm volume must not scale with n"
        );
    }

    #[test]
    fn box_bound_keeps_iterates_inside_eq22_and_still_converges() {
        // Lemma 1: the Eq. 22 domain contains a global optimum, so the
        // constrained run must converge comparably to the unconstrained one.
        let m = low_rank(70, 56, 3, 207);
        let ceiling = (2.0 * m.fro_sq().sqrt()).sqrt() as f32;
        let mk = |box_bound| {
            run_dsanls(
                &m,
                &DsanlsOptions {
                    nodes: 2,
                    rank: 3,
                    iterations: 80,
                    d_u: 20,
                    d_v: 24,
                    eval_every: 0,
                    box_bound,
                    ..Default::default()
                },
            )
        };
        let bounded = mk(true);
        let free = mk(false);
        assert!(bounded.u.max_abs() <= ceiling + 1e-6);
        assert!(bounded.v.max_abs() <= ceiling + 1e-6);
        assert!(
            bounded.final_error() < free.final_error() * 1.5 + 0.02,
            "bounded {} vs free {}",
            bounded.final_error(),
            free.final_error()
        );
    }

    #[test]
    fn sharded_ranks_bit_identical_to_full() {
        // each rank holding only its blocks (plus the chain-reduced exact
        // ‖M‖²) must produce byte-identical factors to ranks that slice
        // the full matrix
        let m = low_rank(66, 45, 3, 209);
        let opts = DsanlsOptions {
            nodes: 3,
            rank: 3,
            iterations: 12,
            d_u: 16,
            d_v: 16,
            eval_every: 4,
            ..Default::default()
        };
        let full = run_dsanls(&m, &opts);
        let outputs = run_cluster(opts.nodes, opts.comm, |ctx| {
            let rr = uniform_partition(m.rows(), opts.nodes).range(ctx.rank);
            let cr = uniform_partition(m.cols(), opts.nodes).range(ctx.rank);
            // build the rank view by slicing (same bytes as shard-local
            // generation, asserted separately in data::shard)
            let mut data = crate::data::shard::NodeData::from_full(&m, rr, cr);
            data.fro_sq = None; // force the chain reduction path
            let fro =
                crate::data::shard::exact_fro_sq(ctx.comm_mut(), opts.nodes, data.m_rows.as_ref())
                    .unwrap();
            assert_eq!(fro.to_bits(), m.fro_sq().to_bits(), "chain ‖M‖² must be exact");
            data.fro_sq = Some(fro);
            dsanls_rank(
                ctx,
                NodeInput::Shard(&data),
                &opts,
                None,
                &RunControl::unsupervised(),
                false,
            )
        });
        let sharded = super::super::reduce_outputs(outputs, opts.rank, opts.iterations);
        assert_eq!(full.u.data(), sharded.u.data(), "U factors diverged");
        assert_eq!(full.v.data(), sharded.v.data(), "V factors diverged");
    }

    #[test]
    fn overlap_is_bit_identical_to_blocking() {
        // the pipeline only reorders factor-independent work, so factors and
        // traced errors must match the blocking schedule exactly
        let m = low_rank(60, 48, 3, 211);
        let mk = |overlap| {
            run_dsanls(
                &m,
                &DsanlsOptions {
                    nodes: 3,
                    rank: 3,
                    iterations: 15,
                    d_u: 16,
                    d_v: 16,
                    eval_every: 5,
                    overlap,
                    ..Default::default()
                },
            )
        };
        let blocking = mk(false);
        let pipelined = mk(true);
        assert_eq!(blocking.u.data(), pipelined.u.data(), "U diverged under overlap");
        assert_eq!(blocking.v.data(), pipelined.v.data(), "V diverged under overlap");
        for (a, b) in blocking.trace.iter().zip(pipelined.trace.iter()) {
            assert_eq!(a.rel_error.to_bits(), b.rel_error.to_bits(), "iter {}", a.iteration);
        }
    }

    #[test]
    fn overlap_works_on_every_sketch_kind() {
        // the _into pipeline covers all four families; spot-check factors
        // against the blocking path for each
        let m = low_rank(40, 36, 3, 213);
        for kind in
            [SketchKind::Gaussian, SketchKind::Subsample, SketchKind::CountSketch, SketchKind::Srht]
        {
            let mk = |overlap| {
                run_dsanls(
                    &m,
                    &DsanlsOptions {
                        nodes: 2,
                        rank: 3,
                        iterations: 6,
                        sketch: kind,
                        d_u: 12,
                        d_v: 12,
                        eval_every: 0,
                        overlap,
                        ..Default::default()
                    },
                )
            };
            let blocking = mk(false);
            let pipelined = mk(true);
            assert_eq!(blocking.u.data(), pipelined.u.data(), "{kind:?} U diverged");
            assert_eq!(blocking.v.data(), pipelined.v.data(), "{kind:?} V diverged");
        }
    }

    #[test]
    fn quantized_wire_halves_bytes_and_still_converges() {
        let m = low_rank(80, 60, 3, 215);
        let mk = |precision| {
            run_dsanls(
                &m,
                &DsanlsOptions {
                    nodes: 3,
                    rank: 3,
                    iterations: 80,
                    d_u: 24,
                    d_v: 24,
                    eval_every: 0,
                    precision,
                    ..Default::default()
                },
            )
        };
        let exact = mk(Precision::F32);
        for precision in [Precision::Bf16, Precision::Fp16] {
            let quant = mk(precision);
            let ratio = exact.total_bytes_sent() as f64 / quant.total_bytes_sent() as f64;
            assert!(
                (1.9..=2.1).contains(&ratio),
                "{precision:?}: byte ratio {ratio} (exact {} vs quant {})",
                exact.total_bytes_sent(),
                quant.total_bytes_sent()
            );
            // convergence equivalence: tolerance, not bit-equality — the
            // wire perturbation is within the format's relative error
            assert!(
                quant.final_error() < exact.final_error() * 1.5 + 0.02,
                "{precision:?}: {} vs exact {}",
                quant.final_error(),
                exact.final_error()
            );
            // and it genuinely perturbs the trajectory (lossy, not a no-op)
            assert_ne!(quant.u.data(), exact.u.data(), "{precision:?} should be lossy");
        }
    }

    #[test]
    fn works_on_sparse_input() {
        let mut rng = Pcg64::new(77, 0);
        let sp = crate::data::synth::power_law_sparse(120, 90, 2000, 4, 1.0, &mut rng);
        let m = Matrix::Sparse(sp);
        let run = run_dsanls(
            &m,
            &DsanlsOptions {
                nodes: 3,
                rank: 4,
                iterations: 60,
                d_u: 30,
                d_v: 30,
                eval_every: 0,
                ..Default::default()
            },
        );
        let first = run.trace.first().unwrap().rel_error;
        assert!(run.final_error() < first, "{} -> {}", first, run.final_error());
    }
}
