//! MPI-FAUN-style distributed baselines: MU, HALS and ANLS/BPP
//! (paper Sec. 2.2.1 / the "MPI-FAUN-*" curves of Fig. 2–4).
//!
//! Per iteration, for the U-subproblem each node needs the **entire** fixed
//! factor `V` (Eq. 5 requires all of V), so the baselines pay:
//!
//! * all-reduce of the k×k gram `VᵀV` (cheap), and
//! * **all-gather of V** — `O(nk)` communication, the term DSANLS's
//!   `O(kd)` all-reduce replaces.
//!
//! Computation per node is `O(k·n·(m/N + k))` versus DSANLS's
//! `O(k·d·(m/N + k))` (paper Sec. 3.6.1) — together these produce the
//! `n/d ≫ 1` speedup the paper claims and Fig. 3 measures.

use super::{assemble_blocks, NodeOutput, ObserverFn, Trace};
use crate::data::partition::uniform_partition;
use crate::data::shard::NodeInput;
use crate::dist::elastic::{run_step, Elastic};
use crate::dist::{CommModel, NodeCtx};
use crate::linalg::{Mat, Matrix};
use crate::nmf::control::{checkpoint_sync, CheckpointMeta, RunControl, StopReason};
use crate::nmf::init_factors_from;
use crate::rng::{Role, StreamRng};
use crate::solvers::{self, Normal, SolverKind};
use crate::transport::wire::Precision;
use crate::transport::Communicator;

/// Stable checkpoint algorithm tag for the MPI-FAUN baselines.
pub const CKPT_TAG: &str = "dist-anls";

/// Fingerprint of every result-affecting baseline option (see
/// [`crate::algos::dsanls::ckpt_params`] for the rationale and what is
/// deliberately excluded).
pub fn ckpt_params(opts: &DistAnlsOptions) -> u64 {
    use crate::nmf::control::{fingerprint_str, params_fingerprint};
    let mut fields = vec![fingerprint_str(opts.solver.name()), opts.inner_sweeps as u64];
    // appended only when non-default so pre-existing checkpoints keep their
    // fingerprint; `overlap` is excluded (bit-identical reordering)
    if opts.precision != Precision::F32 {
        fields.push(fingerprint_str(opts.precision.name()));
    }
    params_fingerprint(&fields)
}

/// Options for an MPI-FAUN-style baseline run.
#[derive(Debug, Clone)]
pub struct DistAnlsOptions {
    pub nodes: usize,
    pub rank: usize,
    pub iterations: usize,
    /// `Mu`, `Hals` or `AnlsBpp` (the three MPI-FAUN instantiations).
    pub solver: SolverKind,
    pub seed: u64,
    pub eval_every: usize,
    pub comm: CommModel,
    /// Inner sweeps per outer iteration for HALS (MPI-FAUN uses 1).
    pub inner_sweeps: usize,
    /// Post the k×k gram reduce and the `O(nk)` factor gather together so
    /// their wire times overlap (bit-identical — collectives stay
    /// rank-ordered, only the schedule changes).
    pub overlap: bool,
    /// Wire precision for the gathered factor blocks ([`Precision::F32`] =
    /// exact). The k×k gram reduce always travels at f32 — it is tiny and
    /// feeds the normal-equation solve directly.
    pub precision: Precision,
}

impl Default for DistAnlsOptions {
    fn default() -> Self {
        DistAnlsOptions {
            nodes: 4,
            rank: 10,
            iterations: 50,
            solver: SolverKind::Hals,
            seed: 42,
            eval_every: 5,
            comm: CommModel::default(),
            inner_sweeps: 1,
            overlap: false,
            precision: Precision::F32,
        }
    }
}

/// One baseline rank over any transport backend — the single per-rank
/// node runner, on a resolved [`NodeInput`] (full matrix, or shard-resident
/// blocks with the exact global `‖M‖²` — see
/// [`crate::algos::dsanls::dsanls_rank`] for the bit-identity contract).
/// `opts.nodes` must match the communicator's cluster size. `ctl` is the
/// run's control plane (per-iteration collective stop poll, checkpoint
/// cadence, resume cursor — the same contract as `dsanls_rank`). `joining`
/// marks a replacement rank entering mid-run via the epoch-join handshake
/// (see `dsanls_rank` — the elastic contract is identical).
pub fn dist_anls_rank<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    input: NodeInput<'_>,
    opts: &DistAnlsOptions,
    observer: Option<&ObserverFn>,
    ctl: &RunControl,
    joining: bool,
) -> NodeOutput {
    assert_eq!(opts.nodes, ctx.nodes(), "opts.nodes must match the cluster size");
    let (rows, cols) = input.dims();
    let row_part = uniform_partition(rows, opts.nodes);
    let col_part = uniform_partition(cols, opts.nodes);
    let rank = ctx.rank;
    let stream = StreamRng::new(opts.seed);
    let my_rows = row_part.range(rank);
    let my_cols = col_part.range(rank);
    let compressed = input.compressed();
    let m_rows_buf = compressed.is_none().then(|| input.row_block(my_rows.clone()));
    let m_rows: Option<&Matrix> = m_rows_buf.as_deref();
    let m_cols_t = compressed.is_none().then(|| input.col_block_t(my_cols.clone()));
    let mut fro_sq = input.fro_sq();
    let mut ws = solvers::Workspace::new();
    if let Some(cb) = compressed {
        assert_eq!(cb.row_range, my_rows, "compressed row range != rank's partition");
        assert_eq!(cb.col_range, my_cols, "compressed col range != rank's partition");
        assert!(!opts.overlap, "overlap × compressed input is rejected at build time");
    }

    let start = ctl.start_iteration();
    let (mut u_block, mut v_block) = if joining {
        // replacement rank: real state (and the real ‖M‖²) arrive through
        // the recovery exchange before the first iteration runs
        (Mat::zeros(my_rows.len(), opts.rank), Mat::zeros(my_cols.len(), opts.rank))
    } else {
        match ctl.resume.as_deref() {
            Some(rs) => (rs.u.row_block(my_rows.clone()), rs.v.row_block(my_cols.clone())),
            None => {
                let (u_full, v_full) = {
                    let mut rng = stream.for_iteration(0, Role::Init);
                    init_factors_from(fro_sq, rows, cols, opts.rank, &mut rng)
                };
                (u_full.row_block(my_rows.clone()), v_full.row_block(my_cols.clone()))
            }
        }
    };

    let ckpt_meta = CheckpointMeta {
        algo: CKPT_TAG.into(),
        seed: opts.seed,
        k: opts.rank,
        rows,
        cols,
        params: ckpt_params(opts),
    };
    let mut trace = Trace::new(if rank == 0 { observer } else { None });
    // sample cursor tracked outside the diverging traces — see `dsanls_rank`
    let mut sampled_at = (!joining).then_some(start);
    if !joining {
        super::dsanls::record_error_any(
            ctx, &input, m_rows, &u_block, &v_block, fro_sq, opts.rank, start, &mut trace,
        );
    }

    let mut stop = StopReason::Completed;
    let mut completed = start;
    let mut elastic = ctl.elastic.map(|e| (Elastic::new(), e.min_ranks));
    let elastic_on = elastic.is_some();
    let mut first_join = joining;
    let mut pending_recovery = joining;
    let mut t = start;
    while t < opts.iterations {
        // elastic recovery: rebuild membership, adopt the committed boundary
        if pending_recovery {
            let (el, min_ranks) = elastic.as_mut().expect("recovery implies elastic");
            let rec = el
                .recover(ctx, *min_ranks, first_join)
                .unwrap_or_else(|e| panic!("rank {rank} elastic recovery: {e}"));
            first_join = false;
            pending_recovery = false;
            t = rec.iteration;
            fro_sq = rec.fro_sq.0;
            let u_len = my_rows.len() * opts.rank;
            u_block = Mat::from_vec(my_rows.len(), opts.rank, rec.state[..u_len].to_vec());
            v_block = Mat::from_vec(my_cols.len(), opts.rank, rec.state[u_len..].to_vec());
            trace.truncate_after(t);
            completed = t;
            sampled_at = None;
            continue;
        }

        let body = || -> Option<StopReason> {
            if let Some((el, _)) = elastic.as_mut() {
                let mut state =
                    Vec::with_capacity(u_block.data().len() + v_block.data().len());
                state.extend_from_slice(u_block.data());
                state.extend_from_slice(v_block.data());
                el.commit(ctx, t, (fro_sq, 0.0), &state);
            }
            // chaos harness: a scripted kill for (rank, t) unwinds here
            ctx.comm_mut().fault_check(t);

            if let Some(reason) = ctl.poll_sync(ctx, t, trace.last_error()) {
                return Some(reason);
            }
            if let Some(cb) = compressed {
                // ---- compressed U-step ----
                // The O(nk) all-gather of V disappears: the summand
                // `B̄_r = (V_{J_r:})ᵀS_{c,J_r:}` all-reduces to `B = VᵀS_c`
                // (k×d_c), and the normal equations come from the resident
                // view — gram = BBᵀ ≈ VᵀV, cross = u_view·Bᵀ ≈ M_{I_r:}V.
                let mut summand = ws.take_summand();
                ctx.compute(|| {
                    cb.s_c().mul_rows_tn_into(&v_block, col_part.offset(rank), &mut summand)
                });
                ctx.all_reduce_sum_q(summand.data_mut(), opts.precision);
                ctx.compute(|| {
                    let nrm = ws.normal_from(cb.u_view(), &summand);
                    for _ in 0..opts.inner_sweeps.max(1) {
                        solvers::update(opts.solver, &mut u_block, &nrm, 0.0);
                    }
                });

                // ---- compressed V-step (mirrored on S_r) ----
                ctx.compute(|| {
                    cb.s_r().mul_rows_tn_into(&u_block, row_part.offset(rank), &mut summand)
                });
                ctx.all_reduce_sum_q(summand.data_mut(), opts.precision);
                ctx.compute(|| {
                    let nrm = ws.normal_from(cb.v_view(), &summand);
                    for _ in 0..opts.inner_sweeps.max(1) {
                        solvers::update(opts.solver, &mut v_block, &nrm, 0.0);
                    }
                });
                ws.restore_summand(summand);

                completed = t + 1;
                if opts.eval_every > 0 && (t + 1) % opts.eval_every == 0 {
                    super::dsanls::record_error_any(
                        ctx, &input, m_rows, &u_block, &v_block, fro_sq, opts.rank, t + 1,
                        &mut trace,
                    );
                    sampled_at = Some(t + 1);
                }
                return None;
            }

            // ---- U-step: gram = VᵀV (all-reduce), V full (all-gather) ----
            // Both collectives depend only on the V of the previous step, so
            // under `overlap` they are posted back to back and waited in post
            // order — the O(nk) gather's wire time hides behind the gram's
            // round trip instead of queueing after it.
            let mut gram_buf = ctx.compute(|| v_block.gram().into_vec());
            let v_blocks = if opts.overlap {
                let p_gram = ctx.all_reduce_start(&gram_buf, Precision::F32);
                let p_gather = ctx.all_gather_start(v_block.data(), opts.precision);
                ctx.all_reduce_finish(p_gram, &mut gram_buf);
                ctx.all_gather_finish(p_gather)
            } else {
                ctx.all_reduce_sum(&mut gram_buf);
                ctx.all_gather_q(v_block.data(), opts.precision) // O(nk) gather
            };
            let gram = Mat::from_vec(opts.rank, opts.rank, gram_buf);
            let v_full = assemble_blocks(&v_blocks, opts.rank);
            ctx.compute(|| {
                let cross = match m_rows.expect("raw input resolves a row block") {
                    Matrix::Dense(md) => md.matmul(&v_full),
                    Matrix::Sparse(ms) => ms.spmm(&v_full),
                };
                let nrm = Normal::new(&gram, &cross);
                for _ in 0..opts.inner_sweeps.max(1) {
                    solvers::update(opts.solver, &mut u_block, &nrm, 0.0);
                }
            });

            // ---- V-step: symmetric with U ----
            let mut gram_buf = ctx.compute(|| u_block.gram().into_vec());
            let u_blocks = if opts.overlap {
                let p_gram = ctx.all_reduce_start(&gram_buf, Precision::F32);
                let p_gather = ctx.all_gather_start(u_block.data(), opts.precision);
                ctx.all_reduce_finish(p_gram, &mut gram_buf);
                ctx.all_gather_finish(p_gather)
            } else {
                ctx.all_reduce_sum(&mut gram_buf);
                ctx.all_gather_q(u_block.data(), opts.precision) // O(mk) gather
            };
            let gram = Mat::from_vec(opts.rank, opts.rank, gram_buf);
            let u_full = assemble_blocks(&u_blocks, opts.rank);
            ctx.compute(|| {
                let cross = match m_cols_t.as_ref().expect("raw input resolves a col block") {
                    Matrix::Dense(md) => md.matmul(&u_full),
                    Matrix::Sparse(ms) => ms.spmm(&u_full),
                };
                let nrm = Normal::new(&gram, &cross);
                for _ in 0..opts.inner_sweeps.max(1) {
                    solvers::update(opts.solver, &mut v_block, &nrm, 0.0);
                }
            });

            completed = t + 1;
            if opts.eval_every > 0 && (t + 1) % opts.eval_every == 0 {
                super::dsanls::record_error_any(
                    ctx, &input, m_rows, &u_block, &v_block, fro_sq, opts.rank, t + 1, &mut trace,
                );
                sampled_at = Some(t + 1);
            }
            if ctl.should_checkpoint(t + 1) {
                checkpoint_sync(
                    ctx,
                    ctl.checkpoint.as_ref().expect("cadence implies config"),
                    &ckpt_meta,
                    t + 1,
                    &u_block,
                    &v_block,
                );
            }
            None
        };
        match if elastic_on { run_step(body) } else { Ok(body()) } {
            Ok(Some(reason)) => {
                stop = reason;
                break;
            }
            Ok(None) => t += 1,
            Err(_lost) => pending_recovery = true,
        }
    }
    if sampled_at != Some(completed) {
        super::dsanls::record_error_any(
            ctx, &input, m_rows, &u_block, &v_block, fro_sq, opts.rank, completed, &mut trace,
        );
    }

    NodeOutput {
        u_block,
        v_block,
        trace: if rank == 0 { trace.into_points() } else { Vec::new() },
        stats: ctx.stats(),
        final_clock: ctx.clock(),
        stop,
        epochs: elastic.as_ref().map_or(1, |(el, _)| el.rebuilds + 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmf::job::{Algo, DataSource, Job};
    use crate::rng::Pcg64;

    fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed as u128, 0);
        let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
        let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
        Matrix::Dense(u.matmul_nt(&v))
    }

    /// Builder-backed shorthand (the deprecated free function is gone).
    fn run_dist_anls(m: &Matrix, opts: &DistAnlsOptions) -> crate::algos::DistRun {
        Job::builder()
            .algorithm(Algo::DistAnls(opts.clone()))
            .data(DataSource::Full(m))
            .run()
            .unwrap_or_else(|e| panic!("baseline job failed: {e}"))
            .into_dist_run()
    }

    #[test]
    fn hals_baseline_converges() {
        let m = low_rank(60, 50, 3, 301);
        let run = run_dist_anls(
            &m,
            &DistAnlsOptions {
                nodes: 3,
                rank: 3,
                iterations: 50,
                solver: SolverKind::Hals,
                inner_sweeps: 2,
                eval_every: 10,
                ..Default::default()
            },
        );
        assert!(run.final_error() < 0.06, "err = {}", run.final_error());
    }

    #[test]
    fn all_three_baselines_decrease_error() {
        let m = low_rank(50, 40, 3, 303);
        for solver in [SolverKind::Mu, SolverKind::Hals, SolverKind::AnlsBpp] {
            let run = run_dist_anls(
                &m,
                &DistAnlsOptions {
                    nodes: 2,
                    rank: 3,
                    iterations: 25,
                    solver,
                    eval_every: 0,
                    ..Default::default()
                },
            );
            let first = run.trace.first().unwrap().rel_error;
            assert!(
                run.final_error() < 0.9 * first,
                "{solver:?}: {} -> {}",
                first,
                run.final_error()
            );
        }
    }

    #[test]
    fn baseline_comm_scales_with_n_unlike_dsanls() {
        // all-gather of V makes baseline traffic grow with n
        let k = 4;
        let opts = DistAnlsOptions {
            nodes: 2,
            rank: k,
            iterations: 10,
            solver: SolverKind::Hals,
            eval_every: 0,
            ..Default::default()
        };
        let small = run_dist_anls(&low_rank(40, 60, 3, 305), &opts);
        let large = run_dist_anls(&low_rank(40, 120, 3, 305), &opts);
        assert!(
            large.total_bytes_sent() > small.total_bytes_sent(),
            "baseline comm must grow with n: {} vs {}",
            small.total_bytes_sent(),
            large.total_bytes_sent()
        );
    }

    #[test]
    fn overlap_is_bit_identical_and_quantized_gather_converges() {
        let m = low_rank(50, 40, 3, 309);
        let mk = |overlap, precision| {
            run_dist_anls(
                &m,
                &DistAnlsOptions {
                    nodes: 2,
                    rank: 3,
                    iterations: 25,
                    solver: SolverKind::Hals,
                    eval_every: 0,
                    overlap,
                    precision,
                    ..Default::default()
                },
            )
        };
        let blocking = mk(false, Precision::F32);
        let pipelined = mk(true, Precision::F32);
        assert_eq!(blocking.u.data(), pipelined.u.data(), "U diverged under overlap");
        assert_eq!(blocking.v.data(), pipelined.v.data(), "V diverged under overlap");

        // quantized gather: fewer bytes, comparable convergence, lossy
        let quant = mk(false, Precision::Bf16);
        assert!(
            quant.total_bytes_sent() < blocking.total_bytes_sent(),
            "bf16 gather must shrink traffic: {} vs {}",
            quant.total_bytes_sent(),
            blocking.total_bytes_sent()
        );
        assert!(
            quant.final_error() < blocking.final_error() * 1.5 + 0.02,
            "quantized {} vs exact {}",
            quant.final_error(),
            blocking.final_error()
        );
        assert_ne!(quant.u.data(), blocking.u.data(), "bf16 should perturb the iterates");
    }

    #[test]
    fn matches_centralized_for_single_node() {
        // N=1 distributed HALS ≡ centralized ANLS-HALS (same seed/init).
        let m = low_rank(30, 24, 3, 307);
        let dist = run_dist_anls(
            &m,
            &DistAnlsOptions {
                nodes: 1,
                rank: 3,
                iterations: 15,
                solver: SolverKind::Hals,
                eval_every: 0,
                inner_sweeps: 1,
                ..Default::default()
            },
        );
        let central = crate::nmf::Anls::new(crate::nmf::AnlsOptions {
            rank: 3,
            iterations: 15,
            solver: SolverKind::Hals,
            seed: 42,
            eval_every: 0,
            inner_sweeps: 1,
        })
        .run(&m);
        assert!(
            (dist.final_error() - central.final_error()).abs() < 1e-6,
            "dist {} vs central {}",
            dist.final_error(),
            central.final_error()
        );
    }
}
