//! Minimal JSON value + serialiser (and a parser for config files).
//! Hand-rolled: serde is not vendored in this environment.

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Serialise compactly.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            JsonValue::String(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            JsonValue::Array(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Object(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    JsonValue::String(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Field lookup on objects.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        if let JsonValue::Object(fields) = self {
            fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
        } else {
            None
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        if let JsonValue::Number(n) = self {
            Some(*n)
        } else {
            None
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        if let JsonValue::String(s) = self {
            Some(s)
        } else {
            None
        }
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<JsonValue, String> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.keyword("true", JsonValue::Bool(true)),
            Some(b'f') => self.keyword("false", JsonValue::Bool(false)),
            Some(b'n') => self.keyword("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn keyword(&mut self, kw: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(format!("bad keyword at {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(JsonValue::Number)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => out.push(c as char),
                None => return Err("eof in string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(JsonValue::Array(items)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(JsonValue::Object(fields)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = JsonValue::Object(vec![
            ("name".into(), JsonValue::String("dsanls".into())),
            ("k".into(), JsonValue::Number(100.0)),
            ("ok".into(), JsonValue::Bool(true)),
            ("trace".into(), JsonValue::Array(vec![JsonValue::Number(0.5), JsonValue::Null])),
        ]);
        let s = v.to_string();
        let back = JsonValue::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let v = JsonValue::parse(r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap(), &JsonValue::Array(vec![
            JsonValue::Number(1.0),
            JsonValue::Number(2.5),
            JsonValue::Object(vec![("b".into(), JsonValue::String("x\ny".into()))]),
        ]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("12 34").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = JsonValue::String("a\"b\\c\n".into());
        let s = v.to_string();
        assert_eq!(JsonValue::parse(&s).unwrap(), v);
    }
}
