//! Metrics output: minimal JSON emitter, CSV trace writer, and the bench
//! report table printer (no serde available offline — hand-rolled).

mod json;

pub use json::JsonValue;

use crate::algos::TracePoint;
use crate::dist::CommStats;
use std::io::Write;
use std::path::Path;

/// A named error-over-time series (one algorithm on one dataset).
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<TracePoint>,
}

impl Series {
    pub fn new(label: impl Into<String>, points: Vec<TracePoint>) -> Self {
        Series { label: label.into(), points }
    }
}

/// Write one or more series as CSV: `label,iteration,sim_time,rel_error`.
pub fn write_series_csv(path: &Path, series: &[Series]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "label,iteration,sim_time_s,rel_error")?;
    for s in series {
        for p in &s.points {
            writeln!(f, "{},{},{:.6e},{:.6e}", s.label, p.iteration, p.sim_time, p.rel_error)?;
        }
    }
    Ok(())
}

/// Write a generic CSV table.
pub fn write_table_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Pretty-print series to stdout the way the paper's figures read:
/// one block per series, error at a few sampled times.
pub fn print_series(title: &str, series: &[Series]) {
    println!("== {title} ==");
    for s in series {
        print!("  {:<16}", s.label);
        let pts = &s.points;
        let n = pts.len();
        let picks: Vec<usize> = if n <= 6 {
            (0..n).collect()
        } else {
            (0..6).map(|i| i * (n - 1) / 5).collect()
        };
        for &i in &picks {
            print!(" t={:.2}s e={:.4}", pts[i].sim_time, pts[i].rel_error);
        }
        println!();
    }
}

/// Aggregate per-node statistics into a printable summary row.
pub fn stats_summary(stats: &[CommStats]) -> String {
    let total_sent: usize = stats.iter().map(|s| s.bytes_sent).sum();
    let max_stall = stats.iter().map(|s| s.stall_time).fold(0.0, f64::max);
    let total_compute: f64 = stats.iter().map(|s| s.compute_time).sum();
    format!(
        "sent={:.2}MB stall_max={:.3}s compute_total={:.3}s",
        total_sent as f64 / 1e6,
        max_stall,
        total_compute
    )
}

/// Convert a trace to a JSON value (for `results/*.json` reports).
pub fn trace_to_json(trace: &[TracePoint]) -> JsonValue {
    JsonValue::Array(
        trace
            .iter()
            .map(|p| {
                JsonValue::Object(vec![
                    ("iteration".into(), JsonValue::Number(p.iteration as f64)),
                    ("sim_time".into(), JsonValue::Number(p.sim_time)),
                    ("rel_error".into(), JsonValue::Number(p.rel_error)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("dsanls_test_metrics");
        let path = dir.join("series.csv");
        let s = Series::new(
            "test",
            vec![TracePoint { iteration: 0, sim_time: 0.0, rel_error: 1.0 }],
        );
        write_series_csv(&path, &[s]).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("label,iteration"));
        assert!(content.contains("test,0"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_json_shape() {
        let t = vec![TracePoint { iteration: 1, sim_time: 0.5, rel_error: 0.25 }];
        let j = trace_to_json(&t).to_string();
        assert!(j.contains("\"rel_error\":0.25"), "{j}");
    }
}
