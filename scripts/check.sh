#!/usr/bin/env bash
# CI gate: formatting, lints, tier-1 build + tests.
# Usage: scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -D warnings (lib first — gates the nmf::job builder API) =="
# the nmf::job module (unified Job builder) is the public front door; keep
# the library clippy-clean on its own before the heavier all-targets pass
cargo clippy --lib -- -D warnings

echo "== cargo clippy -D warnings (all targets) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc (rustdoc must build; transport/ and coordinator/ warn on missing docs) =="
cargo doc --no-deps --quiet

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "all checks passed"
