#!/usr/bin/env bash
# Perf evidence runner: the GEMM microbench (emits BENCH_gemm.json in the
# repo root), the comm-overlap/quantized-wire throughput grid (emits
# BENCH_overlap.json), the serving-plane latency grid (emits
# BENCH_serve.json), the compressed-shard ratio/accuracy sweep (emits
# BENCH_compress.json), the replicated-serving router overhead/failover
# bench (emits BENCH_route.json), plus the Fig. 3 scalability sweep.
#
# Usage: scripts/bench.sh [--full]
#   --full          paper-sized shapes (DSANLS_BENCH_FULL=1)
# Env:  DSANLS_THREADS, DSANLS_SIMD=portable (A/B), DSANLS_BENCH_JSON_DIR
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--full" ]]; then
  export DSANLS_BENCH_FULL=1
fi

echo "== microbench_gemm (writes BENCH_gemm.json) =="
cargo bench --bench microbench_gemm

echo
echo "== overlap_throughput (writes BENCH_overlap.json) =="
cargo bench --bench overlap_throughput

echo
echo "== serve_latency (writes BENCH_serve.json) =="
cargo bench --bench serve_latency

echo
echo "== compress_ratio (writes BENCH_compress.json) =="
cargo bench --bench compress_ratio

echo
echo "== route_failover (writes BENCH_route.json) =="
cargo bench --bench route_failover

echo
echo "== fig3_scalability =="
cargo bench --bench fig3_scalability

echo
echo "done. evidence: ./BENCH_gemm.json, ./BENCH_overlap.json, ./BENCH_serve.json, ./BENCH_compress.json, ./BENCH_route.json, per-figure CSVs under ./results/"
