#!/usr/bin/env bash
# DEPLOYMENT.md localhost walkthrough, executable (CI runs this verbatim):
# shard the dataset, start one worker per "host" on 127.0.0.1, launch with
# a hosts file, and assert the factors are bit-identical to the simulator;
# then the kill/retry, serving, elastic, compressed-shard and replicated-
# serving (router + hot-swap + failover) walkthroughs.
#
# Usage: scripts/deploy_localhost.sh
# Env:   DSANLS_BIN  — dsanls binary (default target/release/dsanls)
#        DSANLS_PORT — rendezvous port (default 47301)
set -euo pipefail
cd "$(dirname "$0")/.."

BIN="${DSANLS_BIN:-target/release/dsanls}"
PORT="${DSANLS_PORT:-47301}"
WORK="$(mktemp -d "${TMPDIR:-/tmp}/dsanls_deploy.XXXXXX")"
trap 'kill $(jobs -p) 2>/dev/null; rm -rf "$WORK"' EXIT

if [[ ! -x "$BIN" ]]; then
  echo "building release binary ($BIN missing)"
  cargo build --release
fi

CFG=(
  --experiment.name=deploy-smoke
  --experiment.algorithm=dsanls
  --experiment.dataset=face
  --experiment.scale=0.05
  --experiment.nodes=2
  --experiment.rank=4
  --experiment.iterations=6
  --experiment.eval_every=3
  "--output.dir=$WORK/results"
)

echo "== step 1: shard the dataset =="
"$BIN" shard --out "$WORK/shards" --nodes 2 "${CFG[@]}"

echo "== step 2/3: start one worker per 'host' (both on 127.0.0.1) =="
"$BIN" worker --rendezvous "127.0.0.1:$PORT" --rank 0 --bind 127.0.0.1 \
  --shards "$WORK/shards" "${CFG[@]}" &
"$BIN" worker --rendezvous "127.0.0.1:$PORT" --rank 1 --bind 127.0.0.1 \
  --shards "$WORK/shards" "${CFG[@]}" &

echo "== step 4: launch with a hosts file, verify against the simulator =="
printf '127.0.0.1\n127.0.0.1\n' > "$WORK/hosts.txt"
"$BIN" launch --port "$PORT" --hosts "$WORK/hosts.txt" \
  --shards "$WORK/shards" --verify-sim "${CFG[@]}" | tee "$WORK/launch.log"

wait

grep -q "bit-identical to simulated backend: true" "$WORK/launch.log"
grep -q "file shard" "$WORK/launch.log"
echo "deployment walkthrough OK (factors bit-identical, workers loaded file shards)"

echo "== step 5: kill a worker mid-run, retry from the checkpoint, verify resume =="
# Fault injection makes rank 1 die at iteration 3; --retries 1 restarts the
# cluster from the last checkpoint, and --verify-sim asserts the resumed
# factors are bit-identical to an uninterrupted simulator run.
"$BIN" launch --nodes 2 --retries 1 \
  --checkpoint "$WORK/run.ckpt" --checkpoint-every 2 \
  --fault-rank 1 --fault-iteration 3 \
  --shards "$WORK/shards" --verify-sim "${CFG[@]}" \
  > "$WORK/retry.log" 2>"$WORK/retry.err" \
  || { cat "$WORK/retry.log" "$WORK/retry.err"; exit 1; }

grep -q "retrying (attempt 1/1)" "$WORK/retry.err"
grep -q "retries: 1" "$WORK/retry.log"
grep -q "bit-identical to simulated backend: true" "$WORK/retry.log"
echo "kill/retry walkthrough OK (rank died mid-run, resumed from checkpoint, bit-identical)"

echo "== step 6: serve the trained factors and query them =="
# Step 5 left the run's checkpoint at $WORK/run.ckpt — the serving plane
# consumes it directly (DEPLOYMENT.md §Serving trained factors).
SERVE_PORT=$((PORT + 1))
"$BIN" serve --checkpoint "$WORK/run.ckpt" --bind "127.0.0.1:$SERVE_PORT" \
  --expect-algo dsanls > "$WORK/serve.log" 2>&1 &
SERVE_PID=$!
for _ in $(seq 1 100); do
  grep -q "serving on" "$WORK/serve.log" 2>/dev/null && break
  sleep 0.1
done
grep -q "serving on" "$WORK/serve.log" || { cat "$WORK/serve.log"; exit 1; }

# the reconstruction row's argmax must lead the same user's top-k list
"$BIN" query --addr "127.0.0.1:$SERVE_PORT" --users 0 --reconstruct \
  | tee "$WORK/reconstruct.log"
ARGMAX="$(sed -n 's/.*argmax=\([0-9]*\).*/\1/p' "$WORK/reconstruct.log")"
test -n "$ARGMAX"
"$BIN" query --addr "127.0.0.1:$SERVE_PORT" --users 0 --top-k 3 | tee "$WORK/topk.log"
grep -q "user 0: $ARGMAX:" "$WORK/topk.log"

# deterministic serving: the identical query answers identically
"$BIN" query --addr "127.0.0.1:$SERVE_PORT" --users 0 --top-k 3 > "$WORK/topk2.log"
cmp "$WORK/topk.log" "$WORK/topk2.log"

# fold-in embeds a new user: a rank-length, printed embedding comes back
"$BIN" query --addr "127.0.0.1:$SERVE_PORT" --fold-in "0:2.0,3:1.0" --top-k 3 \
  | tee "$WORK/fold.log"
test "$(sed -n 's/^fold-in w: //p' "$WORK/fold.log" | wc -w)" -eq 4
grep -q "fold-in top:" "$WORK/fold.log"

# the mirrored item fold-in embeds a new item from user ratings, and
# suggests the users who would score it highest
"$BIN" query --addr "127.0.0.1:$SERVE_PORT" --fold-in-item "0:2.0,1:1.0" --top-k 3 \
  | tee "$WORK/folditem.log"
test "$(sed -n 's/^fold-in-item h: //p' "$WORK/folditem.log" | wc -w)" -eq 4
grep -q "fold-in-item top users:" "$WORK/folditem.log"

# the metrics snapshot reflects the traffic
"$BIN" query --addr "127.0.0.1:$SERVE_PORT" --stats | grep -q '"queries":'

kill "$SERVE_PID" 2>/dev/null
wait "$SERVE_PID" 2>/dev/null || true
echo "serving walkthrough OK (top-k leads with the reconstruction argmax, fold-in embeds, stats live)"

echo "== step 7: elastic fleet — replace the dead worker, no restart =="
# Same scripted death as step 5, but with --elastic the coordinator spawns
# a replacement (worker --join) that re-enters the collective at the next
# membership epoch; the survivors never restart (retries stays 0, epochs
# goes to 2) and the factors are still bit-identical to an uninterrupted
# simulator run (DEPLOYMENT.md §Elastic fleets).
"$BIN" launch --nodes 2 --elastic \
  --fault-rank 1 --fault-iteration 3 \
  --shards "$WORK/shards" --verify-sim "${CFG[@]}" \
  > "$WORK/elastic.log" 2>"$WORK/elastic.err" \
  || { cat "$WORK/elastic.log" "$WORK/elastic.err"; exit 1; }

grep -q "spawning replacement" "$WORK/elastic.err"
! grep -q "retrying" "$WORK/elastic.err"
grep -q "retries: 0" "$WORK/elastic.log"
grep -q "epochs: 2" "$WORK/elastic.log"
grep -q "bit-identical to simulated backend: true" "$WORK/elastic.log"
echo "elastic walkthrough OK (rank died mid-run, replacement re-joined, survivors never restarted, bit-identical)"

echo "== step 8: compressed shards — factorize sketched views directly =="
# --compress writes the fixed sketched views (~1/4 the raw footprint at
# --ratio 4); launch autodetects the v3 format, every worker loads only
# its views, and --verify-sim asserts the compressed run is bit-identical
# to the compressed simulator run (DEPLOYMENT.md §Compressed shards).
"$BIN" shard --out "$WORK/cshards" --nodes 2 --compress --sketch countsketch \
  --ratio 4 "${CFG[@]}" | tee "$WORK/cshard.log"
grep -q "compressed view file" "$WORK/cshard.log"

"$BIN" launch --nodes 2 --shards "$WORK/cshards" --verify-sim "${CFG[@]}" \
  | tee "$WORK/compressed.log"
grep -q "compressed shard" "$WORK/compressed.log"
grep -q "bit-identical to simulated backend: true" "$WORK/compressed.log"

# a secure protocol must refuse the compressed directory with a typed error
CFG_SECURE=()
for a in "${CFG[@]}"; do
  [[ "$a" == --experiment.algorithm=* ]] || CFG_SECURE+=("$a")
done
if "$BIN" launch --nodes 2 --shards "$WORK/cshards" \
    --experiment.algorithm=syn-sd "${CFG_SECURE[@]}" \
    >"$WORK/cerr.out" 2>"$WORK/cerr.log"; then
  echo "secure launch on compressed shards should have failed"; exit 1
fi
grep -qi "secure" "$WORK/cerr.log"
echo "compressed walkthrough OK (sketched views factorized, bit-identical, secure refused)"

echo "== step 9: replicated serving — two replicas, router, hot-swap, failover =="
# Two serve replicas on the step-5 checkpoint behind a consistent-hash
# router; clients keep using plain `dsanls query` against the router
# (DEPLOYMENT.md §Replicated serving). Replica 1 also watches the
# checkpoint file so a rewrite hot-swaps without any admin call.
R1_PORT=$((PORT + 2)); R2_PORT=$((PORT + 3)); ROUTE_PORT=$((PORT + 4))
"$BIN" serve --checkpoint "$WORK/run.ckpt" --bind "127.0.0.1:$R1_PORT" \
  --expect-algo dsanls --watch-checkpoint --watch-interval-ms 200 \
  > "$WORK/replica1.log" 2>&1 &
R1_PID=$!
"$BIN" serve --checkpoint "$WORK/run.ckpt" --bind "127.0.0.1:$R2_PORT" \
  --expect-algo dsanls > "$WORK/replica2.log" 2>&1 &
R2_PID=$!
for _ in $(seq 1 100); do
  grep -q "serving on" "$WORK/replica1.log" 2>/dev/null \
    && grep -q "serving on" "$WORK/replica2.log" 2>/dev/null && break
  sleep 0.1
done
grep -q "serving on" "$WORK/replica2.log" || { cat "$WORK/replica1.log" "$WORK/replica2.log"; exit 1; }

"$BIN" route --replicas "127.0.0.1:$R1_PORT,127.0.0.1:$R2_PORT" \
  --bind "127.0.0.1:$ROUTE_PORT" > "$WORK/route.log" 2>&1 &
ROUTE_PID=$!
for _ in $(seq 1 100); do
  grep -q "routing on" "$WORK/route.log" 2>/dev/null && break
  sleep 0.1
done
grep -q "routing on" "$WORK/route.log" || { cat "$WORK/route.log"; exit 1; }

# the router is transparent: the same query answers exactly as the
# single-server walkthrough in step 6 did (same checkpoint, same factors)
"$BIN" query --addr "127.0.0.1:$ROUTE_PORT" --users 0 --top-k 3 > "$WORK/route_topk1.log"
cmp "$WORK/topk.log" "$WORK/route_topk1.log"

# aggregated stats carry the per-replica breakdown and the fleet generation
"$BIN" query --addr "127.0.0.1:$ROUTE_PORT" --stats | tee "$WORK/route_stats.log" \
  | grep -q '"replicas":'
grep -q '"generation":' "$WORK/route_stats.log"

# rolling hot-swap through the router: every replica re-reads the
# checkpoint and bumps to generation 2 — with identical factors on disk
# the answers must stay bit-identical across the swap
"$BIN" query --addr "127.0.0.1:$ROUTE_PORT" --reload | tee "$WORK/route_reload.log"
grep -q "reloaded: generation 2" "$WORK/route_reload.log"
"$BIN" query --addr "127.0.0.1:$ROUTE_PORT" --users 0 --top-k 3 > "$WORK/route_topk2.log"
cmp "$WORK/route_topk1.log" "$WORK/route_topk2.log"

# replica 1 also watches the file: a rewrite (touch = new mtime) swaps in
# a fresh generation with no admin call at all
sleep 1.1
touch "$WORK/run.ckpt"
for _ in $(seq 1 100); do
  grep -q "swapped to generation" "$WORK/replica1.log" 2>/dev/null && break
  sleep 0.1
done
grep -q "swapped to generation" "$WORK/replica1.log" || { cat "$WORK/replica1.log"; exit 1; }

# kill one replica: the ring fails its keys over and answers stay exact
kill "$R2_PID" 2>/dev/null
wait "$R2_PID" 2>/dev/null || true
"$BIN" query --addr "127.0.0.1:$ROUTE_PORT" --users 0 --top-k 3 > "$WORK/route_topk3.log"
cmp "$WORK/route_topk1.log" "$WORK/route_topk3.log"
"$BIN" query --addr "127.0.0.1:$ROUTE_PORT" --stats | grep -q '"failovers":'

kill "$ROUTE_PID" "$R1_PID" 2>/dev/null
wait "$ROUTE_PID" "$R1_PID" 2>/dev/null || true
echo "replicated serving walkthrough OK (router transparent, rolling reload, watcher swap, kill-one failover)"
