//! Microbench — the L3 hot-path primitives: blocked GEMM (NN/NT/TN),
//! sparse SpMM, sketch application, and one proximal-CD sweep. Used by the
//! §Perf pass (EXPERIMENTS.md) to find and verify hot-path optimisations;
//! prints GFLOP/s against a naive-roofline estimate.

mod bench_util;

use std::time::Instant;

use dsanls::linalg::{gemm_nn, gemm_nt, gemm_tn, Csr, Mat};
use dsanls::rng::Pcg64;
use dsanls::sketch::{SketchKind, SketchMatrix};
use dsanls::solvers::{self, Normal};

fn time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    // warmup
    f();
    let t = Instant::now();
    for _ in 0..reps {
        f();
    }
    t.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    bench_util::banner("microbench", "L3 hot-path primitives");
    let mut rng = Pcg64::new(77, 0);
    let (m, k, n) = if bench_util::full() { (2048, 128, 1024) } else { (768, 64, 512) };

    // --- GEMM family ---
    let a = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let b = Mat::rand_uniform(k, n, 1.0, &mut rng);
    let flops = 2.0 * m as f64 * n as f64 * k as f64;

    let mut c = Mat::zeros(m, n);
    let t_nn = time(|| gemm_nn(&a, &b, &mut c), 5);
    println!("gemm_nn  {m}x{k}x{n}: {:>8.2} ms  {:>6.2} GFLOP/s", t_nn * 1e3, flops / t_nn / 1e9);

    let bt = b.transpose();
    let t_nt = time(|| gemm_nt(&a, &bt, &mut c), 5);
    println!("gemm_nt  {m}x{k}x{n}: {:>8.2} ms  {:>6.2} GFLOP/s", t_nt * 1e3, flops / t_nt / 1e9);

    // gemm_tn: aᵀ·x with a (m×k), x (m×n) → (k×n); same flop count
    let x = Mat::rand_uniform(m, n, 1.0, &mut rng);
    let mut c2 = Mat::zeros(k, n);
    let t_tn = time(|| gemm_tn(&a, &x, &mut c2), 5);
    println!("gemm_tn  {k}x{m}x{n}: {:>8.2} ms  {:>6.2} GFLOP/s", t_tn * 1e3, flops / t_tn / 1e9);

    // --- SpMM ---
    let nnz = m * n / 50;
    let triplets: Vec<(usize, usize, f32)> =
        (0..nnz).map(|_| (rng.below(m), rng.below(n), rng.next_f32())).collect();
    let sp = Csr::from_triplets(m, n, triplets);
    let dense_k = Mat::rand_uniform(n, k, 1.0, &mut rng);
    let t_spmm = time(
        || {
            let _ = sp.spmm(&dense_k);
        },
        5,
    );
    let spmm_flops = 2.0 * sp.nnz() as f64 * k as f64;
    println!(
        "spmm     nnz={} k={k}: {:>8.2} ms  {:>6.2} GFLOP/s",
        sp.nnz(),
        t_spmm * 1e3,
        spmm_flops / t_spmm / 1e9
    );

    // --- sketch apply (both families) ---
    let d = n / 10;
    for kind in [SketchKind::Subsample, SketchKind::Gaussian] {
        let mut srng = Pcg64::new(5, 5);
        let s = SketchMatrix::generate(kind, n, d, &mut srng);
        let t_s = time(
            || {
                let _ = s.mul_right_dense(&c);
            },
            3,
        );
        println!("sketch/{:<11} {m}x{n}→d={d}: {:>8.2} ms", kind.name(), t_s * 1e3);
    }

    // --- proximal CD sweep ---
    let d_cd = 2 * k;
    let a_cd = Mat::rand_uniform(m, d_cd, 1.0, &mut rng);
    let b_cd = Mat::rand_uniform(k, d_cd, 1.0, &mut rng);
    let (gram, cross) = solvers::normal_from(&a_cd, &b_cd);
    let nrm = Normal::new(&gram, &cross);
    let mut u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let t_cd = time(|| solvers::cd::proximal_cd_update(&mut u, &nrm, 1.0), 5);
    let cd_flops = 2.0 * m as f64 * k as f64 * k as f64;
    println!(
        "cd_sweep {m}x{k}: {:>8.2} ms  {:>6.2} GFLOP/s (k² sweep)",
        t_cd * 1e3,
        cd_flops / t_cd / 1e9
    );
}
