//! Microbench — the L3 hot-path primitives: packed GEMM (NN/NT/TN) on both
//! dispatch paths (AVX2 microkernel vs portable fallback), sparse SpMM,
//! sketch application, and one proximal-CD sweep. Used by the §Perf pass
//! (EXPERIMENTS.md) to find and verify hot-path optimisations; prints
//! GFLOP/s and emits a machine-readable `BENCH_gemm.json` report.
//!
//! The acceptance shape for the packed-kernel rework is the 1024³
//! `gemm_nn`: the dispatched path must beat the seed's ~17 GFLOP/s scalar
//! i-k-j kernel by ≥ 2×. Env knobs: `DSANLS_THREADS`, `DSANLS_SIMD=portable`,
//! `DSANLS_BENCH_FULL=1`, `DSANLS_BENCH_JSON_DIR`.

mod bench_util;

use std::time::Instant;

use dsanls::linalg::{gemm_nn, gemm_nt, gemm_tn, set_force_portable, simd_path, Csr, Mat};
use dsanls::metrics::JsonValue;
use dsanls::rng::Pcg64;
use dsanls::sketch::{SketchKind, SketchMatrix};
use dsanls::solvers::{self, Normal};

/// GFLOP/s the seed's scalar i-k-j axpy kernel reached on this bench
/// (EXPERIMENTS.md §Perf, pre-rework baseline) — the ≥2× reference.
const SEED_SCALAR_GFLOPS: f64 = 17.0;

fn time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    // warmup
    f();
    let t = Instant::now();
    for _ in 0..reps {
        f();
    }
    t.elapsed().as_secs_f64() / reps as f64
}

struct GemmRecord {
    kernel: &'static str,
    m: usize,
    k: usize,
    n: usize,
    path: String,
    ms: f64,
    gflops: f64,
}

impl GemmRecord {
    fn to_json(&self) -> JsonValue {
        JsonValue::Object(vec![
            ("kernel".into(), JsonValue::String(self.kernel.into())),
            ("m".into(), JsonValue::Number(self.m as f64)),
            ("k".into(), JsonValue::Number(self.k as f64)),
            ("n".into(), JsonValue::Number(self.n as f64)),
            ("path".into(), JsonValue::String(self.path.clone())),
            ("ms".into(), JsonValue::Number(self.ms * 1e3)),
            ("gflops".into(), JsonValue::Number(self.gflops)),
        ])
    }
}

/// Bench all three GEMM variants on one shape with the current dispatch.
fn bench_gemm_family(
    m: usize,
    k: usize,
    n: usize,
    reps: usize,
    rng: &mut Pcg64,
    records: &mut Vec<GemmRecord>,
) {
    let path = simd_path().to_string();
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let a = Mat::rand_uniform(m, k, 1.0, rng);
    let b = Mat::rand_uniform(k, n, 1.0, rng);

    let mut c = Mat::zeros(m, n);
    let t_nn = time(|| gemm_nn(&a, &b, &mut c), reps);
    println!(
        "gemm_nn  {m}x{k}x{n} [{path:>9}]: {:>8.2} ms  {:>6.2} GFLOP/s",
        t_nn * 1e3,
        flops / t_nn / 1e9
    );
    records.push(GemmRecord { kernel: "gemm_nn", m, k, n, path: path.clone(), ms: t_nn, gflops: flops / t_nn / 1e9 });

    let bt = b.transpose();
    let t_nt = time(|| gemm_nt(&a, &bt, &mut c), reps);
    println!(
        "gemm_nt  {m}x{k}x{n} [{path:>9}]: {:>8.2} ms  {:>6.2} GFLOP/s",
        t_nt * 1e3,
        flops / t_nt / 1e9
    );
    records.push(GemmRecord { kernel: "gemm_nt", m, k, n, path: path.clone(), ms: t_nt, gflops: flops / t_nt / 1e9 });

    // gemm_tn: aᵀ·x with a (m×k), x (m×n) → (k×n); same flop count
    let x = Mat::rand_uniform(m, n, 1.0, rng);
    let mut c2 = Mat::zeros(k, n);
    let t_tn = time(|| gemm_tn(&a, &x, &mut c2), reps);
    println!(
        "gemm_tn  {k}x{m}x{n} [{path:>9}]: {:>8.2} ms  {:>6.2} GFLOP/s",
        t_tn * 1e3,
        flops / t_tn / 1e9
    );
    records.push(GemmRecord { kernel: "gemm_tn", m, k, n, path, ms: t_tn, gflops: flops / t_tn / 1e9 });
}

fn main() {
    bench_util::banner("microbench", "L3 hot-path primitives (packed SIMD GEMM)");
    let mut rng = Pcg64::new(77, 0);
    let mut records: Vec<GemmRecord> = Vec::new();

    // --- GEMM family: NMF-iteration shape + the 1024³ acceptance shape ---
    let dispatch_path = simd_path().to_string(); // before the A/B toggling
    let (m, k, n) = if bench_util::full() { (2048, 128, 1024) } else { (768, 64, 512) };
    bench_gemm_family(m, k, n, 5, &mut rng, &mut records);
    bench_gemm_family(1024, 1024, 1024, 3, &mut rng, &mut records);

    // --- A/B: forced-portable fallback on the acceptance shape ---
    set_force_portable(true);
    bench_gemm_family(1024, 1024, 1024, 3, &mut rng, &mut records);
    // restore the pre-A/B dispatch (preserves a DSANLS_SIMD=portable
    // override instead of unconditionally re-enabling AVX2)
    set_force_portable(dispatch_path == "portable");

    let dispatched = records
        .iter()
        .find(|r| r.kernel == "gemm_nn" && r.m == 1024 && r.path == dispatch_path)
        .or_else(|| records.iter().find(|r| r.kernel == "gemm_nn" && r.m == 1024));
    let portable = records
        .iter()
        .rev()
        .find(|r| r.kernel == "gemm_nn" && r.m == 1024 && r.path == "portable");
    if let Some(d) = dispatched {
        println!(
            "\n1024³ gemm_nn: {} {:.2} GFLOP/s  ({:.2}× the seed scalar kernel's \
             {SEED_SCALAR_GFLOPS} GFLOP/s{})",
            d.path,
            d.gflops,
            d.gflops / SEED_SCALAR_GFLOPS,
            portable
                .map(|p| format!("; portable fallback {:.2} GFLOP/s", p.gflops))
                .unwrap_or_default()
        );
    }

    // --- SpMM ---
    let nnz = m * n / 50;
    let triplets: Vec<(usize, usize, f32)> =
        (0..nnz).map(|_| (rng.below(m), rng.below(n), rng.next_f32())).collect();
    let sp = Csr::from_triplets(m, n, triplets);
    let dense_k = Mat::rand_uniform(n, k, 1.0, &mut rng);
    let t_spmm = time(
        || {
            let _ = sp.spmm(&dense_k);
        },
        5,
    );
    let spmm_flops = 2.0 * sp.nnz() as f64 * k as f64;
    println!(
        "spmm     nnz={} k={k}: {:>8.2} ms  {:>6.2} GFLOP/s",
        sp.nnz(),
        t_spmm * 1e3,
        spmm_flops / t_spmm / 1e9
    );

    // --- sketch apply (both families) ---
    let big = Mat::rand_uniform(m, n, 1.0, &mut rng);
    let d = n / 10;
    for kind in [SketchKind::Subsample, SketchKind::Gaussian] {
        let mut srng = Pcg64::new(5, 5);
        let s = SketchMatrix::generate(kind, n, d, &mut srng);
        let t_s = time(
            || {
                let _ = s.mul_right_dense(&big);
            },
            3,
        );
        println!("sketch/{:<11} {m}x{n}→d={d}: {:>8.2} ms", kind.name(), t_s * 1e3);
    }

    // --- proximal CD sweep ---
    let d_cd = 2 * k;
    let a_cd = Mat::rand_uniform(m, d_cd, 1.0, &mut rng);
    let b_cd = Mat::rand_uniform(k, d_cd, 1.0, &mut rng);
    let (gram, cross) = solvers::normal_from(&a_cd, &b_cd);
    let nrm = Normal::new(&gram, &cross);
    let mut u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let t_cd = time(|| solvers::cd::proximal_cd_update(&mut u, &nrm, 1.0), 5);
    let cd_flops = 2.0 * m as f64 * k as f64 * k as f64;
    println!(
        "cd_sweep {m}x{k}: {:>8.2} ms  {:>6.2} GFLOP/s (k² sweep)",
        t_cd * 1e3,
        cd_flops / t_cd / 1e9
    );

    // --- machine-readable report ---
    let json = JsonValue::Object(vec![
        ("bench".into(), JsonValue::String("microbench_gemm".into())),
        ("threads".into(), JsonValue::Number(dsanls::parallel::num_threads() as f64)),
        ("simd".into(), JsonValue::String(dispatch_path.clone())),
        ("full".into(), JsonValue::Bool(bench_util::full())),
        ("seed_scalar_gflops_1024".into(), JsonValue::Number(SEED_SCALAR_GFLOPS)),
        (
            "speedup_vs_seed_1024".into(),
            dispatched
                .map(|r| JsonValue::Number(r.gflops / SEED_SCALAR_GFLOPS))
                .unwrap_or(JsonValue::Null),
        ),
        ("estimated".into(), JsonValue::Bool(false)),
        (
            "results".into(),
            JsonValue::Array(records.iter().map(|r| r.to_json()).collect()),
        ),
    ]);
    let path = bench_util::write_bench_json("BENCH_gemm.json", &json);
    println!("\nreport written to {path:?}");
}
