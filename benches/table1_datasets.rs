//! Table 1 — dataset statistics. Regenerates the paper's table for the
//! scaled synthetic equivalents and records the achieved sparsity next to
//! the paper's.

mod bench_util;

use dsanls::data::ALL_DATASETS;
use dsanls::metrics::write_table_csv;

fn main() {
    bench_util::banner("Table 1", "dataset statistics (paper vs scaled synthetic)");
    println!(
        "{:<9} | {:>9} {:>7} {:>12} {:>9} | {:>9} {:>7} {:>9}",
        "Dataset", "#Rows", "#Cols", "Non-zeros", "Sparsity", "paper-m", "paper-n", "paper-sp"
    );
    let mut rows = Vec::new();
    for d in ALL_DATASETS {
        let spec = d.spec();
        let m = d.generate_scaled(42, bench_util::scale());
        let sparsity = 1.0 - m.nnz() as f64 / (m.rows() as f64 * m.cols() as f64);
        let sparsity = if spec.dense { 0.0 } else { sparsity };
        println!(
            "{:<9} | {:>9} {:>7} {:>12} {:>8.2}% | {:>9} {:>7} {:>8.2}%",
            spec.name,
            m.rows(),
            m.cols(),
            m.nnz(),
            sparsity * 100.0,
            spec.paper_rows,
            spec.paper_cols,
            spec.paper_sparsity * 100.0
        );
        rows.push(vec![
            spec.name.to_string(),
            m.rows().to_string(),
            m.cols().to_string(),
            m.nnz().to_string(),
            format!("{:.4}", sparsity),
            spec.paper_rows.to_string(),
            spec.paper_cols.to_string(),
            format!("{:.4}", spec.paper_sparsity),
        ]);
    }
    let path = bench_util::results_dir().join("table1_datasets.csv");
    write_table_csv(
        &path,
        &["dataset", "rows", "cols", "nnz", "sparsity", "paper_rows", "paper_cols", "paper_sparsity"],
        &rows,
    )
    .unwrap();
    println!("\nwritten to {path:?}");
}
