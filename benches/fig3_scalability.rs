//! Fig. 3 — reciprocal of per-iteration time vs cluster size (2–16 nodes)
//! for general distributed NMF. Expected shape: near-linear scaling for
//! every algorithm on the larger datasets; flat/degrading on FACE (the
//! smallest — k > n/N makes k dominate, paper Sec. 5.2.2); DSANLS/S lowest
//! per-iteration cost throughout, ANLS/BPP highest.

mod bench_util;

use dsanls::config::Algorithm;
use dsanls::coordinator;
use dsanls::metrics::write_table_csv;
use dsanls::sketch::SketchKind;
use dsanls::solvers::SolverKind;

fn main() {
    bench_util::banner("Fig. 3", "1/per-iteration-time vs node count");
    let datasets: Vec<&str> =
        if bench_util::full() { vec!["FACE", "BOATS", "MNIST", "RCV1"] } else { vec!["FACE", "MNIST"] };
    let nodes = bench_util::node_sweep();
    let mut rows = Vec::new();

    for dataset in datasets {
        let mut cfg = bench_util::base_config();
        cfg.dataset = dataset.into();
        cfg.iterations = bench_util::timing_iters();
        cfg.eval_every = 0; // timing only
        let m = coordinator::load_dataset(&cfg);
        println!("\n--- {dataset} ({}×{}) ---", m.rows(), m.cols());
        println!("{:<18} {}", "algorithm", nodes.iter().map(|n| format!("N={n:<8}")).collect::<String>());

        for (label, algo, sketch) in [
            ("DSANLS/S", Algorithm::Dsanls, Some(SketchKind::Subsample)),
            ("DSANLS/G", Algorithm::Dsanls, Some(SketchKind::Gaussian)),
            ("MU", Algorithm::Baseline(SolverKind::Mu), None),
            ("HALS", Algorithm::Baseline(SolverKind::Hals), None),
            ("ANLS/BPP", Algorithm::Baseline(SolverKind::AnlsBpp), None),
        ] {
            print!("{label:<18}");
            for &n in &nodes {
                let mut c = cfg.clone();
                c.algorithm = algo;
                c.nodes = n;
                if let Some(s) = sketch {
                    c.sketch = s;
                }
                let out = coordinator::run_on(&c, &m);
                let recip = 1.0 / out.sec_per_iter;
                print!("{recip:<9.1}");
                rows.push(vec![
                    dataset.to_string(),
                    label.to_string(),
                    n.to_string(),
                    format!("{:.6}", out.sec_per_iter),
                    format!("{:.3}", recip),
                ]);
            }
            println!();
        }
    }
    let path = bench_util::results_dir().join("fig3_scalability.csv");
    write_table_csv(&path, &["dataset", "algorithm", "nodes", "sec_per_iter", "recip"], &rows)
        .unwrap();
    println!("\nwritten to {path:?}");
}
