//! Ablation A2 — sketch size d sweep (paper footnote 1: "we can set
//! d = 0.1n for medium-sized matrices … we should not choose an extremely
//! small d"). Sweeps d/n ∈ {0.02, 0.05, 0.1, 0.25, 0.5, 1.0} and reports
//! final error + per-iteration cost: too small d stalls convergence, too
//! large d wastes the speedup.

mod bench_util;

use dsanls::algos::DsanlsOptions;
use dsanls::coordinator;
use dsanls::metrics::write_table_csv;
use dsanls::sketch::SketchKind;

use bench_util::run_dsanls;

fn main() {
    bench_util::banner("Ablation A2", "sketch size d sweep");
    let mut cfg = bench_util::base_config();
    cfg.dataset = "FACE".into();
    let m = coordinator::load_dataset(&cfg);
    let n = m.cols();
    println!("{}: {}×{}", cfg.dataset, m.rows(), n);
    println!("{:<10} {:>8} {:>12} {:>14}", "d/n", "d", "final err", "sim-sec/iter");

    let fractions = [0.02f64, 0.05, 0.1, 0.25, 0.5, 1.0];
    let mut rows = Vec::new();
    for frac in fractions {
        let d = ((n as f64 * frac) as usize).max(2).min(n);
        let run = run_dsanls(
            &m,
            &DsanlsOptions {
                nodes: cfg.nodes,
                rank: cfg.rank,
                iterations: cfg.iterations,
                sketch: SketchKind::Subsample,
                d_u: d,
                d_v: ((m.rows() as f64 * frac) as usize).max(2).min(m.rows()),
                seed: cfg.seed,
                eval_every: 0,
                mu: cfg.mu,
                comm: cfg.comm,
                ..Default::default()
            },
        );
        println!(
            "{:<10.2} {:>8} {:>12.4} {:>14.5}",
            frac,
            d,
            run.final_error(),
            run.sec_per_iter
        );
        rows.push(vec![
            format!("{frac}"),
            d.to_string(),
            format!("{:.5}", run.final_error()),
            format!("{:.6}", run.sec_per_iter),
        ]);
    }
    let path = bench_util::results_dir().join("ablation_sketch_size.csv");
    write_table_csv(&path, &["d_over_n", "d", "final_err", "sec_per_iter"], &rows).unwrap();
    println!("\nwritten to {path:?}");
}
