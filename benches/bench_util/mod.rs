#![allow(dead_code)] // each bench binary uses a different helper subset
//! Shared helpers for the figure/table benches.
//!
//! Every bench honours two env vars:
//! * `DSANLS_BENCH_SCALE` — dataset scale factor (default: a quick setting
//!   that finishes the whole `cargo bench` suite in minutes);
//! * `DSANLS_BENCH_FULL=1` — paper-sized sweep (slower, closer shapes).

use std::path::PathBuf;

use dsanls::algos::DsanlsOptions;
use dsanls::config::ExperimentConfig;
use dsanls::linalg::Matrix;
use dsanls::nmf::job::{Algo, DataSource, Job, Outcome};

/// Run DSANLS on `m` through the unified `Job` builder (the shape every
/// DSANLS bench shares).
pub fn run_dsanls(m: &Matrix, opts: &DsanlsOptions) -> Outcome {
    Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(m))
        .run()
        .expect("dsanls job failed")
}

pub fn full() -> bool {
    std::env::var("DSANLS_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

pub fn scale() -> f64 {
    std::env::var("DSANLS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full() { 0.5 } else { 0.08 })
}

pub fn results_dir() -> PathBuf {
    let p = PathBuf::from("results");
    std::fs::create_dir_all(&p).ok();
    p
}

/// Write a machine-readable bench report (e.g. `BENCH_gemm.json`).
///
/// `DSANLS_BENCH_JSON_DIR` overrides the destination directory (default:
/// current directory, so `scripts/bench.sh` run from the repo root leaves
/// the evidence file next to EXPERIMENTS.md).
pub fn write_bench_json(file: &str, value: &dsanls::metrics::JsonValue) -> PathBuf {
    let dir = std::env::var("DSANLS_BENCH_JSON_DIR").map(PathBuf::from).unwrap_or_default();
    let path = if dir.as_os_str().is_empty() { PathBuf::from(file) } else { dir.join(file) };
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).ok();
        }
    }
    std::fs::write(&path, value.to_string()).expect("writing bench json");
    path
}

/// Base config matching the paper's defaults (Sec. 5.1): 10 nodes, k=100 —
/// scaled down for quick mode (k=16, 6 nodes) unless FULL.
pub fn base_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.scale = scale();
    if full() {
        cfg.nodes = 10;
        cfg.rank = 100;
        cfg.iterations = 100;
        cfg.eval_every = 10;
    } else {
        cfg.nodes = 6;
        cfg.rank = 16;
        cfg.iterations = 40;
        cfg.eval_every = 10;
    }
    cfg.t1 = if full() { 25 } else { 10 };
    cfg.t2 = 4;
    cfg.rounds = if full() { 25 } else { 10 };
    cfg.local_iters = 4;
    cfg
}

/// Iterations for pure per-iteration-time measurements (Fig. 3/8/9).
pub fn timing_iters() -> usize {
    if full() {
        20
    } else {
        8
    }
}

pub fn node_sweep() -> Vec<usize> {
    if full() {
        vec![2, 4, 8, 12, 16]
    } else {
        vec![2, 4, 8]
    }
}

/// Shared sweep for Fig. 8 (skew 0) and Fig. 9 (skew 0.5): reciprocal
/// per-iteration time of every secure protocol vs node count.
pub fn secure_scalability_sweep(skew: f64, out_file: &str) {
    use dsanls::config::Algorithm;
    use dsanls::coordinator;
    use dsanls::metrics::write_table_csv;
    use dsanls::secure::SecureAlgo;

    let datasets: Vec<&str> =
        if full() { vec!["FACE", "MNIST", "BOATS"] } else { vec!["FACE", "MNIST"] };
    let nodes = node_sweep();
    let mut rows = Vec::new();
    for dataset in datasets {
        let mut cfg = base_config();
        cfg.dataset = dataset.into();
        cfg.skew = skew;
        cfg.eval_every = 0;
        // timing sweep: fewer, uniform iterations
        cfg.t1 = (timing_iters() / 2).max(2);
        cfg.t2 = 2;
        cfg.rounds = (timing_iters() / 2).max(2);
        cfg.local_iters = 2;
        let m = coordinator::load_dataset(&cfg);
        println!("\n--- {dataset} ({}×{}) skew={skew} ---", m.rows(), m.cols());
        println!(
            "{:<13} {}",
            "protocol",
            nodes.iter().map(|n| format!("N={n:<9}")).collect::<String>()
        );
        for algo in SecureAlgo::ALL {
            print!("{:<13}", algo.name());
            for &n in &nodes {
                let mut c = cfg.clone();
                c.algorithm = Algorithm::Secure(algo);
                c.nodes = n;
                let out = coordinator::run_on(&c, &m);
                let recip = 1.0 / out.sec_per_iter;
                print!("{recip:<10.1}");
                rows.push(vec![
                    dataset.to_string(),
                    algo.name().to_string(),
                    n.to_string(),
                    format!("{skew}"),
                    format!("{:.6}", out.sec_per_iter),
                    format!("{:.3}", recip),
                ]);
            }
            println!();
        }
    }
    let path = results_dir().join(out_file);
    write_table_csv(
        &path,
        &["dataset", "protocol", "nodes", "skew", "sec_per_iter", "recip"],
        &rows,
    )
    .unwrap();
    println!("\nwritten to {path:?}");
}

pub fn banner(name: &str, what: &str) {
    println!("\n================================================================");
    println!("{name} — {what}");
    println!(
        "scale={} nodes_default={} k={} ({} mode)",
        scale(),
        base_config().nodes,
        base_config().rank,
        if full() { "FULL" } else { "quick" }
    );
    println!("================================================================");
}
