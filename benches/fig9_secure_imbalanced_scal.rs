//! Fig. 9 — secure NMF: reciprocal per-iteration time vs cluster size,
//! **imbalanced** workload (node 0 holds 50 % of columns). Expected shape:
//! synchronous protocols flat-line (barrier pinned to node 0's compute);
//! asynchronous protocols keep scaling with node count.

mod bench_util;

fn main() {
    bench_util::banner("Fig. 9", "secure NMF 1/iter-time vs nodes, imbalanced (skew 0.5)");
    bench_util::secure_scalability_sweep(0.5, "fig9_secure_imbalanced_scal.csv");
}
