//! Fig. 8 — secure NMF: reciprocal per-iteration time vs cluster size,
//! uniform workload. Expected shape: near-linear for all (except the tiny
//! FACE); Syn-SSD-UV lowest per-iteration time and steepest slope; full-U
//! synchronous averaging (Syn-SD) the most expensive.

mod bench_util;

fn main() {
    bench_util::banner("Fig. 8", "secure NMF 1/iter-time vs nodes, uniform");
    bench_util::secure_scalability_sweep(0.0, "fig8_secure_scalability.csv");
}
