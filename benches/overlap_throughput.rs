//! Iteration-throughput bench for the comm/compute-overlap + quantized-wire
//! rework: DSANLS through the `Job` builder across the
//! `overlap ∈ {off, on}` × `wire ∈ {f32, bf16, fp16}` grid. Reports the
//! simulated seconds/iteration (the network-model clock the paper's
//! figures use — where overlap hides wire time behind the prefetched
//! GEMMs), host wall-clock per iteration, actual bytes sent (quantized
//! lanes shrink these ~2×), and the final relative error (bit-identical
//! for overlap, mildly lossy for the 16-bit wires). Emits a
//! machine-readable `BENCH_overlap.json` report.
//!
//! Env knobs: `DSANLS_THREADS`, `DSANLS_BENCH_FULL=1`,
//! `DSANLS_BENCH_JSON_DIR`.

mod bench_util;

use std::time::Instant;

use dsanls::algos::DsanlsOptions;
use dsanls::linalg::{Mat, Matrix};
use dsanls::metrics::JsonValue;
use dsanls::nmf::job::{Algo, DataSource, Job, Wire};
use dsanls::rng::Pcg64;

struct Cell {
    overlap: bool,
    wire: Wire,
    sim_sec_per_iter: f64,
    wall_sec_per_iter: f64,
    bytes_sent: usize,
    final_error: f64,
}

impl Cell {
    fn to_json(&self) -> JsonValue {
        JsonValue::Object(vec![
            ("overlap".into(), JsonValue::Bool(self.overlap)),
            ("wire".into(), JsonValue::String(self.wire.to_string())),
            ("sim_sec_per_iter".into(), JsonValue::Number(self.sim_sec_per_iter)),
            ("wall_ms_per_iter".into(), JsonValue::Number(self.wall_sec_per_iter * 1e3)),
            ("bytes_sent".into(), JsonValue::Number(self.bytes_sent as f64)),
            ("final_error".into(), JsonValue::Number(self.final_error)),
        ])
    }
}

fn main() {
    bench_util::banner(
        "overlap_throughput",
        "comm-overlap + quantized-wire DSANLS iteration throughput",
    );
    let (rows, cols, k) =
        if bench_util::full() { (2400usize, 1800usize, 64usize) } else { (720, 540, 16) };
    let nodes = if bench_util::full() { 10 } else { 6 };
    let iterations = bench_util::timing_iters() * 2;
    let (d_u, d_v) = (3 * k, 4 * k);

    let mut rng = Pcg64::new(0x0E51A9, 0);
    let u0 = Mat::rand_uniform(rows, k, 1.0, &mut rng);
    let v0 = Mat::rand_uniform(cols, k, 1.0, &mut rng);
    let m = Matrix::Dense(u0.matmul_nt(&v0));

    let opts = DsanlsOptions {
        nodes,
        rank: k,
        iterations,
        d_u,
        d_v,
        eval_every: 0,
        ..Default::default()
    };

    let mut cells: Vec<Cell> = Vec::new();
    println!(
        "{:<8} {:<5} {:>14} {:>12} {:>10} {:>10}",
        "overlap", "wire", "sim ms/iter", "wall ms/it", "MB sent", "rel_err"
    );
    for overlap in [false, true] {
        for wire in [Wire::F32, Wire::Bf16, Wire::Fp16] {
            let t = Instant::now();
            let out = Job::builder()
                .algorithm(Algo::Dsanls(opts.clone()))
                .data(DataSource::Full(&m))
                .overlap_comm(overlap)
                .wire_precision(wire)
                .run()
                .expect("bench job failed");
            let wall = t.elapsed().as_secs_f64() / iterations as f64;
            let cell = Cell {
                overlap,
                wire,
                sim_sec_per_iter: out.sec_per_iter,
                wall_sec_per_iter: wall,
                bytes_sent: out.total_bytes_sent(),
                final_error: out.final_error(),
            };
            println!(
                "{:<8} {:<5} {:>14.3} {:>12.2} {:>10.2} {:>10.5}",
                cell.overlap,
                cell.wire.to_string(),
                cell.sim_sec_per_iter * 1e3,
                cell.wall_sec_per_iter * 1e3,
                cell.bytes_sent as f64 / 1e6,
                cell.final_error
            );
            cells.push(cell);
        }
    }

    let find = |overlap: bool, wire: Wire| {
        cells.iter().find(|c| c.overlap == overlap && c.wire == wire).unwrap()
    };
    let blocking = find(false, Wire::F32);
    let overlapped = find(true, Wire::F32);
    let quantized = find(true, Wire::Bf16);
    let overlap_speedup = blocking.sim_sec_per_iter / overlapped.sim_sec_per_iter;
    let bytes_ratio = blocking.bytes_sent as f64 / quantized.bytes_sent as f64;
    println!(
        "\noverlap hides wire time: {overlap_speedup:.3}× simulated-clock speedup at f32; \
         bf16 wire sends {bytes_ratio:.2}× fewer bytes"
    );

    let json = JsonValue::Object(vec![
        ("bench".into(), JsonValue::String("overlap_throughput".into())),
        ("threads".into(), JsonValue::Number(dsanls::parallel::num_threads() as f64)),
        ("nodes".into(), JsonValue::Number(nodes as f64)),
        ("rank".into(), JsonValue::Number(k as f64)),
        ("iterations".into(), JsonValue::Number(iterations as f64)),
        ("full".into(), JsonValue::Bool(bench_util::full())),
        ("overlap_speedup_sim".into(), JsonValue::Number(overlap_speedup)),
        ("bf16_bytes_ratio".into(), JsonValue::Number(bytes_ratio)),
        ("estimated".into(), JsonValue::Bool(false)),
        ("results".into(), JsonValue::Array(cells.iter().map(|c| c.to_json()).collect())),
    ]);
    let path = bench_util::write_bench_json("BENCH_overlap.json", &json);
    println!("report written to {path:?}");
}
