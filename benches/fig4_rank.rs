//! Fig. 4 — rel-error over time on RCV1, varying the factorisation rank
//! k ∈ {20, 50, 200, 500} (k=100 is Fig. 2e). Expected shape: DSANLS
//! outperforms the baselines at every k; error decreases with k but
//! convergence takes longer.

mod bench_util;

use dsanls::config::Algorithm;
use dsanls::coordinator;
use dsanls::metrics::{write_series_csv, Series};
use dsanls::sketch::SketchKind;
use dsanls::solvers::SolverKind;

fn main() {
    bench_util::banner("Fig. 4", "varying k on RCV1");
    let ks: Vec<usize> = if bench_util::full() { vec![20, 50, 200, 500] } else { vec![8, 24] };

    let mut cfg = bench_util::base_config();
    cfg.dataset = "RCV1".into();
    let m = coordinator::load_dataset(&cfg);
    println!("RCV1 (scaled): {}×{}, nnz={}", m.rows(), m.cols(), m.nnz());

    for k in ks {
        let mut series: Vec<Series> = Vec::new();
        println!("\n--- k = {k} ---");
        for (algo, sketch) in [
            (Algorithm::Dsanls, Some(SketchKind::Subsample)),
            (Algorithm::Baseline(SolverKind::Hals), None),
            (Algorithm::Baseline(SolverKind::AnlsBpp), None),
        ] {
            let mut c = cfg.clone();
            c.algorithm = algo;
            c.rank = k;
            if let Some(s) = sketch {
                c.sketch = s;
            }
            let out = coordinator::run_on(&c, &m);
            println!(
                "  {:<18} final err {:.4}  sim-sec/iter {:.4}",
                out.label,
                out.final_error(),
                out.sec_per_iter
            );
            series.push(out.series());
        }
        let path = bench_util::results_dir().join(format!("fig4_rcv1_k{k}.csv"));
        write_series_csv(&path, &series).unwrap();
        println!("written to {path:?}");
    }
}
