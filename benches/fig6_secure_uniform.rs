//! Fig. 6 — secure distributed NMF, uniform workload: rel-error over time
//! for all six protocols on BOATS/FACE/MNIST/GISETTE. Expected shape:
//! Syn-SSD-UV best overall (cheapest per-iteration), Syn-SD and Asyn-SD
//! slowest to converge.

mod bench_util;

use dsanls::config::Algorithm;
use dsanls::coordinator;
use dsanls::metrics::{write_series_csv, Series};
use dsanls::secure::SecureAlgo;

fn main() {
    bench_util::banner("Fig. 6", "secure NMF, uniform workload");
    let datasets: Vec<&str> = if bench_util::full() {
        vec!["BOATS", "FACE", "MNIST", "GISETTE"]
    } else {
        vec!["FACE", "MNIST"]
    };
    for dataset in datasets {
        let mut cfg = bench_util::base_config();
        cfg.dataset = dataset.into();
        cfg.skew = 0.0;
        let m = coordinator::load_dataset(&cfg);
        println!("\n--- {dataset} ({}×{}) ---", m.rows(), m.cols());
        let mut series: Vec<Series> = Vec::new();
        for algo in SecureAlgo::ALL {
            let mut c = cfg.clone();
            c.algorithm = Algorithm::Secure(algo);
            let out = coordinator::run_on(&c, &m);
            println!(
                "  {:<12} final err {:.4}  sim-sec/iter {:.5}",
                out.label,
                out.final_error(),
                out.sec_per_iter
            );
            series.push(out.series());
        }
        let path = bench_util::results_dir()
            .join(format!("fig6_{}.csv", dataset.to_lowercase()));
        write_series_csv(&path, &series).unwrap();
        println!("written to {path:?}");
    }
}
