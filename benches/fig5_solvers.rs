//! Fig. 5 — per-iteration convergence of the two DSANLS subproblem
//! solvers: proximal coordinate descent (RCD) vs projected gradient
//! descent (PGD), for both sketch families. Expected shape: RCD converges
//! faster per iteration regardless of the random-matrix type.

mod bench_util;

use dsanls::algos::DsanlsOptions;
use dsanls::coordinator;
use dsanls::metrics::{write_series_csv, Series};
use dsanls::sketch::SketchKind;
use dsanls::solvers::SolverKind;

use bench_util::run_dsanls;

fn main() {
    bench_util::banner("Fig. 5", "RCD vs PGD subproblem solvers (per iteration)");
    let mut cfg = bench_util::base_config();
    cfg.dataset = if bench_util::full() { "BOATS".into() } else { "FACE".into() };
    let m = coordinator::load_dataset(&cfg);
    println!("{}: {}×{}", cfg.dataset, m.rows(), m.cols());

    let mut series: Vec<Series> = Vec::new();
    for sketch in [SketchKind::Subsample, SketchKind::Gaussian] {
        for solver in [SolverKind::ProximalCd, SolverKind::Pgd] {
            let run = run_dsanls(
                &m,
                &DsanlsOptions {
                    nodes: cfg.nodes,
                    rank: cfg.rank,
                    iterations: cfg.iterations,
                    solver,
                    sketch,
                    d_u: cfg.d_u,
                    d_v: cfg.d_v,
                    seed: cfg.seed,
                    eval_every: cfg.eval_every.max(1),
                    mu: cfg.mu,
                    comm: cfg.comm,
                    box_bound: false,
                },
            );
            let label = format!(
                "DSANLS-{}/{}",
                if solver == SolverKind::ProximalCd { "RCD" } else { "PGD" },
                if sketch == SketchKind::Subsample { "S" } else { "G" },
            );
            println!("  {:<16} final err {:.4}", label, run.final_error());
            series.push(Series::new(label, run.trace));
        }
    }
    // headline: RCD final error ≤ PGD final error for each sketch
    for pair in series.chunks(2) {
        let (rcd, pgd) = (&pair[0], &pair[1]);
        let e_rcd = rcd.points.last().unwrap().rel_error;
        let e_pgd = pgd.points.last().unwrap().rel_error;
        println!(
            "  {} {:.4} vs {} {:.4} → RCD {}",
            rcd.label,
            e_rcd,
            pgd.label,
            e_pgd,
            if e_rcd <= e_pgd { "wins (paper shape ✓)" } else { "LOSES (unexpected)" }
        );
    }
    let path = bench_util::results_dir().join("fig5_solvers.csv");
    write_series_csv(&path, &series).unwrap();
    println!("written to {path:?}");
}
