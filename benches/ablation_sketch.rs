//! Ablation A1 — sketch-family comparison (paper Sec. 3.4 discussion +
//! future-work families): Gaussian vs Subsampling vs CountSketch vs SRHT
//! on a dense and a sparse dataset. Reports per-iteration convergence AND
//! per-iteration cost, exposing the trade-off the paper describes:
//! Gaussian = more informative columns / O(mnd) cost, Subsampling =
//! sparsity-preserving / O(md) cost.

mod bench_util;

use dsanls::algos::DsanlsOptions;
use dsanls::coordinator;
use dsanls::metrics::{write_series_csv, Series};
use dsanls::sketch::SketchKind;

use bench_util::run_dsanls;

fn main() {
    bench_util::banner("Ablation A1", "sketch families on DSANLS");
    let datasets: Vec<&str> = if bench_util::full() { vec!["FACE", "MNIST"] } else { vec!["FACE"] };
    for dataset in datasets {
        let mut cfg = bench_util::base_config();
        cfg.dataset = dataset.into();
        let m = coordinator::load_dataset(&cfg);
        println!("\n--- {dataset} ({}×{}) ---", m.rows(), m.cols());
        let mut series: Vec<Series> = Vec::new();
        for sketch in [
            SketchKind::Subsample,
            SketchKind::Gaussian,
            SketchKind::CountSketch,
            SketchKind::Srht,
        ] {
            let run = run_dsanls(
                &m,
                &DsanlsOptions {
                    nodes: cfg.nodes,
                    rank: cfg.rank,
                    iterations: cfg.iterations,
                    sketch,
                    d_u: cfg.d_u,
                    d_v: cfg.d_v,
                    seed: cfg.seed,
                    eval_every: cfg.eval_every.max(1),
                    mu: cfg.mu,
                    comm: cfg.comm,
                    ..Default::default()
                },
            );
            println!(
                "  {:<12} final err {:.4}  sim-sec/iter {:.5}",
                sketch.name(),
                run.final_error(),
                run.sec_per_iter
            );
            series.push(Series::new(sketch.name(), run.trace));
        }
        let path = bench_util::results_dir()
            .join(format!("ablation_sketch_{}.csv", dataset.to_lowercase()));
        write_series_csv(&path, &series).unwrap();
        println!("written to {path:?}");
    }
}
