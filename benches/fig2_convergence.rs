//! Fig. 2 — relative error over time for general distributed NMF:
//! DSANLS/S and DSANLS/G vs MPI-FAUN {MU, HALS, ANLS/BPP} on all six
//! datasets. Expected shape (paper): DSANLS/S best error-vs-time
//! everywhere; MU slow with poor final error; ANLS/BPP hurt by its
//! per-iteration cost.

mod bench_util;

use dsanls::config::Algorithm;
use dsanls::coordinator;
use dsanls::data::ALL_DATASETS;
use dsanls::metrics::{print_series, write_series_csv, Series};
use dsanls::sketch::SketchKind;
use dsanls::solvers::SolverKind;

fn main() {
    bench_util::banner("Fig. 2", "rel-error over time, general distributed NMF");
    let datasets: Vec<_> = if bench_util::full() {
        ALL_DATASETS.to_vec()
    } else {
        // quick mode: one dense + one sparse dataset keeps the suite fast
        vec![dsanls::data::Dataset::Face, dsanls::data::Dataset::Mnist]
    };

    for dataset in datasets {
        let mut cfg = bench_util::base_config();
        cfg.dataset = dataset.spec().name.into();
        let m = coordinator::load_dataset(&cfg);
        println!("\n--- {} ({}×{}, nnz={}) ---", cfg.dataset, m.rows(), m.cols(), m.nnz());

        let mut series: Vec<Series> = Vec::new();
        for (algo, sketch) in [
            (Algorithm::Dsanls, Some(SketchKind::Subsample)),
            (Algorithm::Dsanls, Some(SketchKind::Gaussian)),
            (Algorithm::Baseline(SolverKind::Mu), None),
            (Algorithm::Baseline(SolverKind::Hals), None),
            (Algorithm::Baseline(SolverKind::AnlsBpp), None),
        ] {
            let mut c = cfg.clone();
            c.algorithm = algo;
            if let Some(s) = sketch {
                c.sketch = s;
            }
            let out = coordinator::run_on(&c, &m);
            println!(
                "  {:<18} final err {:.4}  sim-sec/iter {:.4}",
                out.label,
                out.final_error(),
                out.sec_per_iter
            );
            series.push(out.series());
        }
        print_series(&format!("Fig2 {}", cfg.dataset), &series);
        let path = bench_util::results_dir()
            .join(format!("fig2_{}.csv", cfg.dataset.to_lowercase()));
        write_series_csv(&path, &series).unwrap();
        println!("written to {path:?}");
    }
}
