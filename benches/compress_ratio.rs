//! Compressed-data-plane bench: DSANLS factorizing *sketched shards*
//! (`dsanls shard --compress`) across the compression-ratio ×
//! sketch-family grid. For each cell the harness writes a compressed
//! directory, runs the compressed job end-to-end through the `Job`
//! builder, and reports:
//!
//! * per-rank resident bytes (the residency win — ≈ raw/R for the
//!   structured CountSketch, views-only + dense sketch for Gaussian),
//! * host wall-clock per iteration (sketched GEMMs shrink with `d`),
//! * the compressed-domain residual proxy the run traces, and
//! * the **exact** recovery error of the produced factors against the raw
//!   matrix (which only the bench, never a rank, holds) — the
//!   ratio-vs-accuracy curve DEPLOYMENT.md cites.
//!
//! A raw (`ratio = 1`, uncompressed `DataSource::Full`) row anchors both
//! columns. Emits a machine-readable `BENCH_compress.json` report.
//!
//! Env knobs: `DSANLS_THREADS`, `DSANLS_BENCH_FULL=1`,
//! `DSANLS_BENCH_JSON_DIR`.

mod bench_util;

use std::path::PathBuf;
use std::time::Instant;

use dsanls::algos::DsanlsOptions;
use dsanls::data::compress::{ratio_dims, write_compressed_dir};
use dsanls::data::shard::ShardManifest;
use dsanls::linalg::{Mat, Matrix};
use dsanls::metrics::JsonValue;
use dsanls::nmf::job::{Algo, DataSource, Job, Outcome};
use dsanls::rng::Pcg64;
use dsanls::sketch::SketchKind;

struct Cell {
    kind: &'static str,
    ratio: f64,
    resident_bytes: usize,
    wall_sec_per_iter: f64,
    proxy_error: f64,
    recovery_error: f64,
}

impl Cell {
    fn to_json(&self) -> JsonValue {
        JsonValue::Object(vec![
            ("sketch".into(), JsonValue::String(self.kind.into())),
            ("ratio".into(), JsonValue::Number(self.ratio)),
            ("resident_bytes".into(), JsonValue::Number(self.resident_bytes as f64)),
            ("wall_ms_per_iter".into(), JsonValue::Number(self.wall_sec_per_iter * 1e3)),
            ("proxy_error".into(), JsonValue::Number(self.proxy_error)),
            ("recovery_error".into(), JsonValue::Number(self.recovery_error)),
        ])
    }
}

fn resident(out: &Outcome) -> usize {
    out.loads.iter().map(|l| l.bytes).sum()
}

fn main() {
    bench_util::banner("compress_ratio", "factorize-from-sketched-shards ratio/accuracy sweep");
    let (rows, cols, k) =
        if bench_util::full() { (2400usize, 1800usize, 32usize) } else { (600, 480, 8) };
    let nodes = 4usize;
    let iterations = bench_util::timing_iters() * 2;

    let mut rng = Pcg64::new(0xC0B9E55, 0);
    let u0 = Mat::rand_uniform(rows, k, 1.0, &mut rng);
    let v0 = Mat::rand_uniform(cols, k, 1.0, &mut rng);
    let m = Matrix::Dense(u0.matmul_nt(&v0));
    let raw_block_bytes = {
        // one rank's raw row + col block, the residency baseline
        4 * (rows.div_ceil(nodes) * cols + rows * cols.div_ceil(nodes))
    };

    let opts = DsanlsOptions { nodes, rank: k, iterations, eval_every: 0, ..Default::default() };

    let mut cells: Vec<Cell> = Vec::new();
    println!(
        "{:<12} {:>6} {:>14} {:>12} {:>11} {:>11}",
        "sketch", "ratio", "resident MB", "wall ms/it", "proxy err", "recov err"
    );

    // raw anchor row: the uncompressed job on the same matrix
    {
        let t = Instant::now();
        let out = Job::builder()
            .algorithm(Algo::Dsanls(opts.clone()))
            .data(DataSource::Full(&m))
            .run()
            .expect("raw bench job failed");
        let cell = Cell {
            kind: "raw",
            ratio: 1.0,
            resident_bytes: raw_block_bytes,
            wall_sec_per_iter: t.elapsed().as_secs_f64() / iterations as f64,
            proxy_error: out.final_error(),
            recovery_error: out.check_error(&m),
        };
        print_cell(&cell);
        cells.push(cell);
    }

    for (kind, name) in
        [(SketchKind::Gaussian, "subgaussian"), (SketchKind::CountSketch, "countsketch")]
    {
        for ratio in [2.0f64, 4.0, 8.0] {
            let dir = scratch_dir(name, ratio);
            let base = ShardManifest::uniform(
                nodes,
                rows,
                cols,
                m.fro_sq(),
                7,
                1.0,
                true,
                "FACE".into(),
            );
            let (d_r, d_c) = ratio_dims(rows, cols, ratio).expect("valid ratio");
            write_compressed_dir(&dir, &m, &base, kind, d_r, d_c)
                .expect("writing compressed shards failed");

            let t = Instant::now();
            let out = Job::builder()
                .algorithm(Algo::Dsanls(opts.clone()))
                .data(DataSource::Compressed(dir.clone()))
                .run()
                .expect("compressed bench job failed");
            let cell = Cell {
                kind: name,
                ratio,
                resident_bytes: resident(&out) / nodes,
                wall_sec_per_iter: t.elapsed().as_secs_f64() / iterations as f64,
                proxy_error: out.final_error(),
                recovery_error: out.check_error(&m),
            };
            print_cell(&cell);
            cells.push(cell);
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    let best_ratio = cells
        .iter()
        .filter(|c| c.kind == "countsketch")
        .map(|c| raw_block_bytes as f64 / c.resident_bytes as f64)
        .fold(0.0f64, f64::max);
    println!(
        "\ncountsketch shards shrink per-rank residency up to {best_ratio:.1}× vs raw blocks \
         (recovery degrades gracefully with the ratio — see the recov-err column)"
    );

    let json = JsonValue::Object(vec![
        ("bench".into(), JsonValue::String("compress_ratio".into())),
        ("threads".into(), JsonValue::Number(dsanls::parallel::num_threads() as f64)),
        ("rows".into(), JsonValue::Number(rows as f64)),
        ("cols".into(), JsonValue::Number(cols as f64)),
        ("nodes".into(), JsonValue::Number(nodes as f64)),
        ("rank".into(), JsonValue::Number(k as f64)),
        ("iterations".into(), JsonValue::Number(iterations as f64)),
        ("raw_block_bytes".into(), JsonValue::Number(raw_block_bytes as f64)),
        ("full".into(), JsonValue::Bool(bench_util::full())),
        ("best_residency_ratio".into(), JsonValue::Number(best_ratio)),
        ("estimated".into(), JsonValue::Bool(false)),
        ("results".into(), JsonValue::Array(cells.iter().map(|c| c.to_json()).collect())),
    ]);
    let path = bench_util::write_bench_json("BENCH_compress.json", &json);
    println!("report written to {path:?}");
}

fn print_cell(c: &Cell) {
    println!(
        "{:<12} {:>6.1} {:>14.3} {:>12.2} {:>11.5} {:>11.5}",
        c.kind,
        c.ratio,
        c.resident_bytes as f64 / 1e6,
        c.wall_sec_per_iter * 1e3,
        c.proxy_error,
        c.recovery_error
    );
}

fn scratch_dir(kind: &str, ratio: f64) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dsanls_bench_compress_{kind}_{ratio}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("creating bench scratch dir");
    dir
}
