//! Replicated-serving router bench: a consistent-hash `dsanls route`
//! front-end over in-process serve replicas, all on real TCP loopback.
//! Measures (1) the routing overhead — direct-to-replica vs
//! through-the-router p50/p99 top-k latency, (2) degraded-fleet
//! throughput after one replica is killed (the ring fails its keys over
//! to the survivors), and (3) the failover hiccup: how long the first
//! query routed at a just-killed replica takes to come back from the
//! next ring node. Emits a machine-readable `BENCH_route.json` report.
//!
//! Env knobs: `DSANLS_THREADS`, `DSANLS_BENCH_FULL=1`,
//! `DSANLS_BENCH_JSON_DIR`.

mod bench_util;

use std::time::{Duration, Instant};

use dsanls::linalg::Mat;
use dsanls::metrics::JsonValue;
use dsanls::nmf::control::{Checkpoint, CheckpointMeta, ResumeState};
use dsanls::rng::Pcg64;
use dsanls::router::{route, RouteOptions};
use dsanls::serve::{serve, FactorModel, ServeClient, ServeOptions, ServerHandle};

fn model(users: usize, items: usize, k: usize) -> FactorModel {
    let mut rng = Pcg64::new(0x40F7E, k as u128);
    let u = Mat::rand_uniform(users, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(items, k, 1.0, &mut rng);
    FactorModel::from_checkpoint(Checkpoint {
        meta: CheckpointMeta { algo: "dsanls".into(), seed: 1, k, rows: users, cols: items, params: 0 },
        state: ResumeState { iteration: 1, u, v },
    })
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

fn replica(users: usize, items: usize, k: usize) -> ServerHandle {
    let opts = ServeOptions { batch_wait_us: 0, ..ServeOptions::default() };
    serve("127.0.0.1:0", model(users, items, k), opts).expect("bind replica")
}

/// p50/p99 top-k latency and queries/s of `queries` sequential queries
/// against `addr`.
fn measure(addr: &str, users: usize, queries: usize, top: usize) -> (f64, f64, f64) {
    let mut client = ServeClient::connect(addr).expect("connect");
    for q in 0..5u64 {
        client.top_k(&[q % users as u64], top).expect("warmup query");
    }
    let mut lat = Vec::with_capacity(queries);
    let t0 = Instant::now();
    for q in 0..queries {
        let user = (q as u64 * 7919) % users as u64;
        let t = Instant::now();
        client.top_k(&[user], top).expect("bench query");
        lat.push(t.elapsed().as_secs_f64());
    }
    let total = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    (percentile(&lat, 0.50) * 1e3, percentile(&lat, 0.99) * 1e3, queries as f64 / total)
}

fn main() {
    bench_util::banner("route_failover", "consistent-hash router overhead and failover");
    let full = bench_util::full();
    let (users, items, k) = if full { (20_000usize, 8_000usize, 64) } else { (4_000, 2_000, 32) };
    let queries = if full { 600usize } else { 200 };
    let top = 10;

    // --- routing overhead: direct replica vs router-in-the-middle -------
    let mut solo = replica(users, items, k);
    let (direct_p50, direct_p99, direct_qps) =
        measure(&solo.addr().to_string(), users, queries, top);
    println!("direct:  p50 {direct_p50:.3} ms  p99 {direct_p99:.3} ms  {direct_qps:.0} q/s");

    let mut r2 = replica(users, items, k);
    let replicas = vec![solo.addr().to_string(), r2.addr().to_string()];
    let opts = RouteOptions { cooldown: Duration::from_millis(200), ..RouteOptions::default() };
    let mut router = route("127.0.0.1:0", &replicas, opts).expect("bind router");
    let (routed_p50, routed_p99, routed_qps) =
        measure(&router.addr().to_string(), users, queries, top);
    println!("routed:  p50 {routed_p50:.3} ms  p99 {routed_p99:.3} ms  {routed_qps:.0} q/s");

    // --- failover hiccup + degraded throughput --------------------------
    // kill one replica, then probe 16 distinct user keys: the slowest of
    // them almost surely hashed to the dead replica, so its latency is
    // the failover-detection cost (dead pooled socket + refused redial)
    let mut probe = ServeClient::connect(&router.addr().to_string()).expect("connect probe");
    r2.shutdown();
    let mut first_after_kill_ms = 0.0f64;
    for user in 0..16u64 {
        let t = Instant::now();
        probe.top_k(&[user], top).expect("failover query");
        first_after_kill_ms = first_after_kill_ms.max(t.elapsed().as_secs_f64() * 1e3);
    }
    drop(probe);
    let (degraded_p50, degraded_p99, degraded_qps) =
        measure(&router.addr().to_string(), users, queries, top);
    println!(
        "killed one replica: first query {first_after_kill_ms:.3} ms, degraded p50 \
         {degraded_p50:.3} ms  p99 {degraded_p99:.3} ms  {degraded_qps:.0} q/s"
    );
    let m = router.metrics_json();
    let failovers = m.get("failovers").and_then(JsonValue::as_f64).unwrap_or(0.0);
    router.shutdown();
    solo.shutdown();

    let json = JsonValue::Object(vec![
        ("bench".into(), JsonValue::String("route_failover".into())),
        ("threads".into(), JsonValue::Number(dsanls::parallel::num_threads() as f64)),
        ("users".into(), JsonValue::Number(users as f64)),
        ("items".into(), JsonValue::Number(items as f64)),
        ("k".into(), JsonValue::Number(k as f64)),
        ("queries".into(), JsonValue::Number(queries as f64)),
        ("top_k".into(), JsonValue::Number(top as f64)),
        ("full".into(), JsonValue::Bool(full)),
        ("direct_p50_ms".into(), JsonValue::Number(direct_p50)),
        ("direct_p99_ms".into(), JsonValue::Number(direct_p99)),
        ("direct_qps".into(), JsonValue::Number(direct_qps)),
        ("routed_p50_ms".into(), JsonValue::Number(routed_p50)),
        ("routed_p99_ms".into(), JsonValue::Number(routed_p99)),
        ("routed_qps".into(), JsonValue::Number(routed_qps)),
        ("first_query_after_kill_ms".into(), JsonValue::Number(first_after_kill_ms)),
        ("degraded_p50_ms".into(), JsonValue::Number(degraded_p50)),
        ("degraded_p99_ms".into(), JsonValue::Number(degraded_p99)),
        ("degraded_qps".into(), JsonValue::Number(degraded_qps)),
        ("failovers".into(), JsonValue::Number(failovers)),
        ("estimated".into(), JsonValue::Bool(false)),
    ]);
    let path = bench_util::write_bench_json("BENCH_route.json", &json);
    println!("report written to {path:?}");
}
