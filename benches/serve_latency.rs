//! Serving-plane latency/throughput bench: an in-process `dsanls serve`
//! server answering a sequential client over real TCP loopback. Sweeps
//! the (rank k × users-per-query) grid and reports per-query p50/p99
//! latency plus scored-rows/s for top-k queries, and the fold-in solve
//! throughput (cache-miss solves/s and cache-hit lookups/s) — the numbers
//! behind the serve section of EXPERIMENTS.md. Emits a machine-readable
//! `BENCH_serve.json` report.
//!
//! Env knobs: `DSANLS_THREADS`, `DSANLS_BENCH_FULL=1`,
//! `DSANLS_BENCH_JSON_DIR`.

mod bench_util;

use std::time::Instant;

use dsanls::linalg::Mat;
use dsanls::metrics::JsonValue;
use dsanls::nmf::control::{Checkpoint, CheckpointMeta, ResumeState};
use dsanls::rng::Pcg64;
use dsanls::serve::{serve, FactorModel, ServeClient, ServeOptions};

struct Cell {
    k: usize,
    batch: usize,
    p50_ms: f64,
    p99_ms: f64,
    rows_per_s: f64,
}

impl Cell {
    fn to_json(&self) -> JsonValue {
        JsonValue::Object(vec![
            ("k".into(), JsonValue::Number(self.k as f64)),
            ("batch".into(), JsonValue::Number(self.batch as f64)),
            ("p50_ms".into(), JsonValue::Number(self.p50_ms)),
            ("p99_ms".into(), JsonValue::Number(self.p99_ms)),
            ("rows_per_s".into(), JsonValue::Number(self.rows_per_s)),
        ])
    }
}

fn model(users: usize, items: usize, k: usize) -> FactorModel {
    let mut rng = Pcg64::new(0x5E4E, k as u128);
    let u = Mat::rand_uniform(users, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(items, k, 1.0, &mut rng);
    FactorModel::from_checkpoint(Checkpoint {
        meta: CheckpointMeta { algo: "dsanls".into(), seed: 1, k, rows: users, cols: items, params: 0 },
        state: ResumeState { iteration: 1, u, v },
    })
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

fn main() {
    bench_util::banner("serve_latency", "serving-plane query latency and fold-in throughput");
    let full = bench_util::full();
    let (users, items) = if full { (20_000usize, 8_000usize) } else { (4_000, 2_000) };
    let ks: Vec<usize> = if full { vec![32, 64, 128] } else { vec![16, 64] };
    let batches: Vec<usize> = vec![1, 8, 32];
    let queries = if full { 400usize } else { 120 };
    let top = 10;

    let mut cells: Vec<Cell> = Vec::new();
    println!(
        "{:<6} {:<6} {:>10} {:>10} {:>12}",
        "k", "batch", "p50 ms", "p99 ms", "rows/s"
    );
    for &k in &ks {
        let m = model(users, items, k);
        // batch_wait_us=0: a sequential client measures the no-coalescing
        // floor — each query is its own GEMM
        let opts = ServeOptions { batch_wait_us: 0, ..ServeOptions::default() };
        let mut handle = serve("127.0.0.1:0", m, opts).expect("bind serve");
        let mut client = ServeClient::connect(&handle.addr().to_string()).expect("connect");

        for &batch in &batches {
            let ids: Vec<u64> = (0..batch as u64).collect();
            // warm-up sizes the batcher scratch for this shape
            for _ in 0..5 {
                client.top_k(&ids, top).expect("warmup query");
            }
            let mut lat = Vec::with_capacity(queries);
            let t0 = Instant::now();
            for q in 0..queries {
                let ids: Vec<u64> =
                    (0..batch as u64).map(|i| (q as u64 * 7 + i * 13) % users as u64).collect();
                let t = Instant::now();
                client.top_k(&ids, top).expect("bench query");
                lat.push(t.elapsed().as_secs_f64());
            }
            let total = t0.elapsed().as_secs_f64();
            lat.sort_by(|a, b| a.total_cmp(b));
            let cell = Cell {
                k,
                batch,
                p50_ms: percentile(&lat, 0.50) * 1e3,
                p99_ms: percentile(&lat, 0.99) * 1e3,
                rows_per_s: (queries * batch) as f64 / total,
            };
            println!(
                "{:<6} {:<6} {:>10.3} {:>10.3} {:>12.0}",
                cell.k, cell.batch, cell.p50_ms, cell.p99_ms, cell.rows_per_s
            );
            cells.push(cell);
        }
        handle.shutdown();
    }

    // fold-in throughput at the middle rank: all-miss solves (distinct
    // rows) vs all-hit lookups (one row repeated)
    let k = ks[ks.len() / 2];
    let m = model(users, items, k);
    let opts = ServeOptions { batch_wait_us: 0, ..ServeOptions::default() };
    let mut handle = serve("127.0.0.1:0", m, opts).expect("bind serve");
    let mut client = ServeClient::connect(&handle.addr().to_string()).expect("connect");
    let solves = if full { 600usize } else { 200 };
    let row = |s: usize| -> Vec<(u64, f32)> {
        (0..16).map(|i| (((s * 31 + i * 17) % items) as u64, 1.0 + i as f32 * 0.1)).collect()
    };
    for s in 0..5 {
        client.fold_in(&row(s + solves), 0).expect("warmup fold");
    }
    let t0 = Instant::now();
    for s in 0..solves {
        client.fold_in(&row(s), 0).expect("fold miss");
    }
    let miss_per_s = solves as f64 / t0.elapsed().as_secs_f64();
    let hot = row(0);
    let t0 = Instant::now();
    for _ in 0..solves {
        client.fold_in(&hot, 0).expect("fold hit");
    }
    let hit_per_s = solves as f64 / t0.elapsed().as_secs_f64();
    println!(
        "\nfold-in at k={k}: {miss_per_s:.0} solves/s (cache miss), \
         {hit_per_s:.0} lookups/s (cache hit)"
    );
    handle.shutdown();

    let json = JsonValue::Object(vec![
        ("bench".into(), JsonValue::String("serve_latency".into())),
        ("threads".into(), JsonValue::Number(dsanls::parallel::num_threads() as f64)),
        ("users".into(), JsonValue::Number(users as f64)),
        ("items".into(), JsonValue::Number(items as f64)),
        ("queries_per_cell".into(), JsonValue::Number(queries as f64)),
        ("top_k".into(), JsonValue::Number(top as f64)),
        ("full".into(), JsonValue::Bool(full)),
        ("fold_in_k".into(), JsonValue::Number(k as f64)),
        ("fold_in_miss_per_s".into(), JsonValue::Number(miss_per_s)),
        ("fold_in_hit_per_s".into(), JsonValue::Number(hit_per_s)),
        ("estimated".into(), JsonValue::Bool(false)),
        ("results".into(), JsonValue::Array(cells.iter().map(|c| c.to_json()).collect())),
    ]);
    let path = bench_util::write_bench_json("BENCH_serve.json", &json);
    println!("report written to {path:?}");
}
