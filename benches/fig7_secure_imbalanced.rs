//! Fig. 7 — secure distributed NMF, imbalanced workload (node 0 holds
//! 50 % of the columns). Expected shape: asynchronous protocols win —
//! Asyn-SSD-V best error-over-time on most datasets; Syn-SD basically
//! inapplicable (synchronisation barrier stalls everyone behind node 0).

mod bench_util;

use dsanls::config::Algorithm;
use dsanls::coordinator;
use dsanls::metrics::{write_series_csv, Series};
use dsanls::secure::SecureAlgo;

fn main() {
    bench_util::banner("Fig. 7", "secure NMF, imbalanced workload (50% on node 0)");
    let datasets: Vec<&str> = if bench_util::full() {
        vec!["BOATS", "FACE", "MNIST", "GISETTE"]
    } else {
        vec!["FACE", "MNIST"]
    };
    for dataset in datasets {
        let mut cfg = bench_util::base_config();
        cfg.dataset = dataset.into();
        cfg.skew = 0.5;
        let m = coordinator::load_dataset(&cfg);
        println!("\n--- {dataset} ({}×{}) skew=0.5 ---", m.rows(), m.cols());
        let mut series: Vec<Series> = Vec::new();
        let mut sync_times = Vec::new();
        let mut async_times = Vec::new();
        for algo in SecureAlgo::ALL {
            let mut c = cfg.clone();
            c.algorithm = Algorithm::Secure(algo);
            let out = coordinator::run_on(&c, &m);
            println!(
                "  {:<12} final err {:.4}  sim-sec/iter {:.5}",
                out.label,
                out.final_error(),
                out.sec_per_iter
            );
            match algo {
                SecureAlgo::AsynSd | SecureAlgo::AsynSsdV => async_times.push(out.sec_per_iter),
                _ => sync_times.push(out.sec_per_iter),
            }
            series.push(out.series());
        }
        let sync_avg: f64 = sync_times.iter().sum::<f64>() / sync_times.len() as f64;
        let async_avg: f64 = async_times.iter().sum::<f64>() / async_times.len() as f64;
        println!(
            "  async/sync per-iteration advantage: {:.2}× {}",
            sync_avg / async_avg,
            if async_avg < sync_avg { "(paper shape ✓)" } else { "(unexpected)" }
        );
        let path = bench_util::results_dir()
            .join(format!("fig7_{}.csv", dataset.to_lowercase()));
        write_series_csv(&path, &series).unwrap();
        println!("written to {path:?}");
    }
}
