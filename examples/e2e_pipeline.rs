//! END-TO-END driver: exercises the **full system** on a real small
//! workload and proves all three layers compose (DESIGN.md §6).
//!
//! Pipeline:
//! 1.  generate the scaled MNIST workload from `data::datasets` (Table 1);
//! 2.  load the AOT artifacts (python/JAX/Pallas → HLO text) through PJRT
//!     and verify the compiled update step against the native solver on
//!     real operands sliced from the workload;
//! 3.  run the headline comparison — DSANLS/S and DSANLS/G vs the three
//!     MPI-FAUN baselines — on a 10-node simulated cluster (a Fig. 2
//!     panel) and report relative error over simulated time;
//! 4.  run all six secure protocols (a Fig. 6 panel);
//! 5.  write every trace to `results/e2e/*.csv` and print the headline
//!     metrics that EXPERIMENTS.md records.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::path::Path;

use dsanls::config::{Algorithm, ExperimentConfig};
use dsanls::coordinator;
use dsanls::linalg::Mat;
use dsanls::metrics::{self, Series};
use dsanls::rng::Pcg64;
use dsanls::runtime::{LocalSolver, NativeBackend, PjrtBackend, PjrtRuntime};
use dsanls::secure::SecureAlgo;
use dsanls::sketch::SketchKind;
use dsanls::solvers::SolverKind;

fn main() -> dsanls::Result<()> {
    let out_dir = Path::new("results/e2e");

    // ---- 1. workload -------------------------------------------------------
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "MNIST".into();
    cfg.scale = 0.35; // ~2450×460 sparse
    cfg.nodes = 10;
    cfg.rank = 16;
    cfg.iterations = 60;
    cfg.eval_every = 10;
    cfg.t1 = 15;
    cfg.t2 = 4;
    cfg.rounds = 15;
    cfg.local_iters = 4;
    let m = coordinator::load_dataset(&cfg);
    println!(
        "workload: scaled MNIST {}×{}, nnz={} ({:.1}% dense)",
        m.rows(),
        m.cols(),
        m.nnz(),
        100.0 * m.nnz() as f64 / (m.rows() as f64 * m.cols() as f64)
    );

    // ---- 2. PJRT layer-composition check ------------------------------------
    match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Ok(rt) => {
            println!("\n[L1/L2⇄L3] PJRT platform: {}", rt.platform());
            let backend = PjrtBackend::new(rt);
            // real operands: slice a 128-row block of the workload, sketch to d=32
            let dense = m.row_block(0..128).to_dense();
            let mut srng = Pcg64::new(999, 0);
            let s = dsanls::sketch::SketchMatrix::generate(
                SketchKind::Subsample,
                dense.cols(),
                32,
                &mut srng,
            );
            let a = s.mul_right_dense(&dense);
            let mut vrng = Pcg64::new(1000, 0);
            let v = Mat::rand_uniform(dense.cols(), 16, 0.5, &mut vrng);
            let b = s.mul_rows_tn(&v, 0);
            let u0 = Mat::rand_uniform(128, 16, 0.5, &mut vrng);
            let mut u_pjrt = u0.clone();
            backend.cd_update(&mut u_pjrt, &a, &b, 1.0)?;
            let mut u_native = u0;
            NativeBackend.cd_update(&mut u_native, &a, &b, 1.0)?;
            let diff = u_pjrt.dist_sq(&u_native).sqrt();
            println!("  compiled Pallas CD vs native on real operands: ‖Δ‖ = {diff:.2e}");
            assert!(diff < 1e-3, "layer composition broken");
        }
        Err(e) => println!("\n[L1/L2⇄L3] skipped ({e}) — run `make artifacts`"),
    }

    // ---- 3. general NMF headline (Fig. 2 panel) -----------------------------
    println!("\n[general] DSANLS vs MPI-FAUN baselines, {} nodes, k={}:", cfg.nodes, cfg.rank);
    let mut general = Vec::new();
    for (algo, sketch) in [
        (Algorithm::Dsanls, Some(SketchKind::Subsample)),
        (Algorithm::Dsanls, Some(SketchKind::Gaussian)),
        (Algorithm::Baseline(SolverKind::Mu), None),
        (Algorithm::Baseline(SolverKind::Hals), None),
        (Algorithm::Baseline(SolverKind::AnlsBpp), None),
    ] {
        let mut c = cfg.clone();
        c.algorithm = algo;
        if let Some(s) = sketch {
            c.sketch = s;
        }
        let out = coordinator::run_on(&c, &m);
        println!(
            "  {:<16} err {:.4}  sim-sec/iter {:.4}  {}",
            out.label,
            out.final_error(),
            out.sec_per_iter,
            metrics::stats_summary(&out.stats)
        );
        general.push((out.label.clone(), out));
    }
    let series: Vec<Series> = general.iter().map(|(_, o)| o.series()).collect();
    metrics::write_series_csv(&out_dir.join("general_nmf.csv"), &series)?;

    // headline checks (the paper's qualitative claims)
    let get = |label: &str| {
        general.iter().find(|(l, _)| l == label).map(|(_, o)| o).expect("missing run")
    };
    let dsanls_s = get("DSANLS/S");
    let bpp = get("MPI-FAUN-ANLS-BPP");
    println!(
        "\n  headline: DSANLS/S {:.2}× faster per-iteration than ANLS/BPP \
         (paper: BPP has the highest per-iteration cost)",
        bpp.sec_per_iter / dsanls_s.sec_per_iter
    );
    assert!(dsanls_s.sec_per_iter < bpp.sec_per_iter, "DSANLS must beat BPP per-iteration");

    // ---- 4. secure protocols (Fig. 6 panel) ---------------------------------
    println!("\n[secure] six protocols, uniform workload:");
    let mut secure_series = Vec::new();
    for algo in SecureAlgo::ALL {
        let mut c = cfg.clone();
        c.algorithm = Algorithm::Secure(algo);
        let out = coordinator::run_on(&c, &m);
        println!(
            "  {:<12} err {:.4}  sim-sec/iter {:.5}",
            out.label,
            out.final_error(),
            out.sec_per_iter
        );
        secure_series.push(out.series());
    }
    metrics::write_series_csv(&out_dir.join("secure_nmf.csv"), &secure_series)?;

    println!("\ntraces written to {out_dir:?}");
    println!("e2e_pipeline OK");
    Ok(())
}
