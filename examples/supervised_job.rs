//! Supervised execution: run a DSANLS job in the **background** through
//! `Job::spawn()`, drain live progress, checkpoint on a cadence, cancel
//! it mid-run, and resume from the checkpoint to the factors the
//! uninterrupted run would have produced — bit for bit.
//!
//! ```bash
//! cargo run --release --example supervised_job
//! ```

use std::time::Duration;

use dsanls::algos::DsanlsOptions;
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, DataSource, Job};
use dsanls::nmf::StopReason;
use dsanls::rng::Pcg64;

fn main() -> dsanls::Result<()> {
    let mut rng = Pcg64::new(7, 0);
    let m = {
        let u0 = Mat::rand_uniform(400, 6, 1.0, &mut rng);
        let v0 = Mat::rand_uniform(300, 6, 1.0, &mut rng);
        Matrix::Dense(u0.matmul_nt(&v0))
    };
    let opts = DsanlsOptions {
        nodes: 4,
        rank: 6,
        iterations: 400,
        d_u: 40,
        d_v: 50,
        eval_every: 10,
        ..Default::default()
    };
    let ckpt = std::env::temp_dir().join(format!("supervised_job_{}.ckpt", std::process::id()));

    // --- 1. the reference: the same job run uninterrupted ------------------
    let reference = Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(&m))
        .run()?;

    // --- 2. spawn supervised, drain progress, cancel mid-run ---------------
    // (a spawned job owns its data; progress streams through the handle)
    let handle = Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(&m))
        .checkpoint_every(20, &ckpt)
        .spawn()?;
    println!("job spawned; draining progress until the first checkpoint…");
    let mut seen = 0usize;
    while !ckpt.exists() && !handle.is_finished() {
        for e in handle.drain_progress() {
            seen += 1;
            println!("  iter {:>4}  err={:.4}", e.iteration, e.rel_error);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    handle.cancel(); // cooperative: returns within one iteration
    let cancelled = handle.wait()?;
    println!(
        "cancelled cleanly after {} traced samples: stop={:?}, last err={:.4}",
        seen,
        cancelled.stop_reason,
        cancelled.final_error()
    );
    // (on a very fast machine the job may have completed before the cancel
    // landed — both outcomes are clean)
    assert!(matches!(
        cancelled.stop_reason,
        StopReason::Cancelled | StopReason::Completed
    ));

    // --- 3. resume from the checkpoint and finish ---------------------------
    if cancelled.stop_reason == StopReason::Completed {
        println!("job completed before the cancel landed — nothing to resume");
        std::fs::remove_file(&ckpt).ok();
        return Ok(());
    }
    let resumed = Job::builder()
        .algorithm(Algo::Dsanls(opts))
        .data(DataSource::Full(&m))
        .resume_from(&ckpt)
        .run()?;
    assert_eq!(
        reference.u.data(),
        resumed.u.data(),
        "resumed factors must be bit-identical to the uninterrupted run"
    );
    assert_eq!(reference.v.data(), resumed.v.data());
    println!(
        "resumed to completion: err={:.4} — bit-identical to the uninterrupted run",
        resumed.final_error()
    );
    std::fs::remove_file(&ckpt).ok();
    Ok(())
}
