//! Quickstart: factorise a small synthetic matrix with DSANLS on a
//! 4-node simulated cluster, then verify the AOT/PJRT backend produces the
//! same update step as the native solver.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use dsanls::algos::{DsanlsOptions, ProgressEvent};
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, Backend, DataSource, Job};
use dsanls::rng::Pcg64;
use dsanls::runtime::{LocalSolver, NativeBackend, PjrtBackend, PjrtRuntime};
use dsanls::sketch::SketchKind;

fn main() -> dsanls::Result<()> {
    // --- 1. a rank-8 nonnegative matrix with noise -------------------------
    let mut rng = Pcg64::new(2024, 0);
    let m = {
        let u0 = Mat::rand_uniform(600, 8, 1.0, &mut rng);
        let v0 = Mat::rand_uniform(400, 8, 1.0, &mut rng);
        Matrix::Dense(u0.matmul_nt(&v0))
    };
    println!("input: {}x{} dense, ‖M‖={:.1}", m.rows(), m.cols(), m.fro_sq().sqrt());

    // --- 2. DSANLS on a 4-node simulated cluster, via the Job builder ------
    // The observer streams every traced sample live (no waiting for the
    // post-hoc series); swap `.transport(Backend::Tcp { port: 0 })` in to
    // run the identical job over real localhost sockets instead.
    let nodes = 4;
    let observer = |e: &ProgressEvent| {
        println!(
            "  iter {:>4}  t={:.3}s  err={:.4}  ({:.1} KB sent so far on rank 0)",
            e.iteration,
            e.sim_time,
            e.rel_error,
            e.stats.bytes_sent as f64 / 1e3
        );
    };
    println!("\nDSANLS/S convergence (streamed while the job runs):");
    let run = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions {
            nodes,
            rank: 8,
            iterations: 150,
            sketch: SketchKind::Subsample,
            d_u: 60, // sketch size d ≪ n=400
            d_v: 80,
            eval_every: 25,
            ..Default::default()
        }))
        .data(DataSource::Full(&m))
        .transport(Backend::Sim)
        .observer(&observer)
        .run()?;
    println!(
        "final error {:.4}; {:.1} KB total communication ({} nodes)",
        run.final_error(),
        run.total_bytes_sent() as f64 / 1e3,
        nodes
    );
    assert!(run.final_error() < 0.1, "quickstart did not converge");

    // --- 3. the compiled Pallas kernel path (PJRT) -------------------------
    match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Ok(rt) => {
            println!("\nPJRT backend up ({}), checking AOT vs native step…", rt.platform());
            let backend = PjrtBackend::new(rt);
            let (rows, k, d) = (128usize, 16usize, 32usize);
            let a = Mat::rand_uniform(rows, d, 1.0, &mut rng);
            let b = Mat::rand_uniform(k, d, 1.0, &mut rng);
            let u0 = Mat::rand_uniform(rows, k, 1.0, &mut rng);
            let mut u_pjrt = u0.clone();
            backend.cd_update(&mut u_pjrt, &a, &b, 1.0)?;
            let mut u_native = u0;
            NativeBackend.cd_update(&mut u_native, &a, &b, 1.0)?;
            let diff = u_pjrt.dist_sq(&u_native).sqrt();
            println!("  ‖U_pjrt − U_native‖ = {diff:.2e}  (Pallas kernel == rust solver)");
            assert!(diff < 1e-3);
        }
        Err(e) => println!("\n(PJRT backend skipped: {e})"),
    }

    println!("\nquickstart OK");
    Ok(())
}
