//! Topic mining on a sparse term–document matrix (the paper's text-mining
//! motivation, cf. RCV1): factorise a power-law bag-of-words matrix with
//! DSANLS/Subsampling — the sparsity-preserving sketch — and report the
//! per-topic top terms plus the n/d computation saving.
//!
//! ```bash
//! cargo run --release --example topic_mining
//! ```

use dsanls::algos::{DistAnlsOptions, DsanlsOptions};
use dsanls::data::synth;
use dsanls::linalg::Matrix;
use dsanls::nmf::job::{Algo, DataSource, Job};
use dsanls::rng::Pcg64;
use dsanls::sketch::SketchKind;
use dsanls::solvers::SolverKind;

fn main() {
    // 2000 documents × 1500 terms, ~8 planted topics, Zipf-distributed terms
    let mut rng = Pcg64::new(4242, 0);
    let docs = synth::power_law_sparse(2000, 1500, 60_000, 8, 1.05, &mut rng);
    let density = docs.density();
    let m = Matrix::Sparse(docs);
    println!(
        "term-document matrix: {}×{}, nnz={} ({:.2}% dense)",
        m.rows(),
        m.cols(),
        m.nnz(),
        density * 100.0
    );

    let k = 8;
    let d = 150; // = n/10, the paper's default sketch size

    // --- DSANLS/S ----------------------------------------------------------
    let ds = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions {
            nodes: 5,
            rank: k,
            iterations: 100,
            sketch: SketchKind::Subsample,
            d_u: d,
            d_v: 200,
            eval_every: 20,
            ..Default::default()
        }))
        .data(DataSource::Full(&m))
        .run()
        .expect("DSANLS job failed");
    println!("\nDSANLS/S   : err {:.4}, {:.4} sim-sec/iter", ds.final_error(), ds.sec_per_iter);

    // --- distributed HALS baseline (MPI-FAUN style) -------------------------
    let hals = Job::builder()
        .algorithm(Algo::DistAnls(DistAnlsOptions {
            nodes: 5,
            rank: k,
            iterations: 100,
            solver: SolverKind::Hals,
            eval_every: 20,
            ..Default::default()
        }))
        .data(DataSource::Full(&m))
        .run()
        .expect("HALS job failed");
    println!("dist-HALS  : err {:.4}, {:.4} sim-sec/iter", hals.final_error(), hals.sec_per_iter);
    println!(
        "per-iteration speedup {:.1}× (paper predicts ~n/d = {:.1}× ceiling on compute)",
        hals.sec_per_iter / ds.sec_per_iter,
        1500.0 / d as f64
    );
    println!(
        "communication: DSANLS {:.1} KB vs HALS {:.1} KB",
        ds.total_bytes_sent() as f64 / 1e3,
        hals.total_bytes_sent() as f64 / 1e3
    );

    // --- topics: top terms per factor column --------------------------------
    println!("\ntop terms per topic (term indices, weight):");
    let v = &ds.v; // terms × k
    for topic in 0..k {
        let mut weights: Vec<(usize, f32)> =
            (0..v.rows()).map(|t| (t, v.get(t, topic))).collect();
        weights.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let top: Vec<String> =
            weights.iter().take(5).map(|(t, w)| format!("#{t}({w:.2})")).collect();
        println!("  topic {topic}: {}", top.join(" "));
    }

    assert!(ds.final_error() <= hals.final_error() * 1.25, "DSANLS should stay competitive");
    println!("\ntopic_mining OK");
}
