//! End-to-end recommender flow on the serving plane: **train** a small
//! DSANLS factorisation with checkpointing, **load** the checkpoint into
//! a [`FactorModel`], **serve** it over TCP, and run the three query
//! families a recommender needs — batched top-k for known users, full
//! reconstruction rows, and fold-in for a brand-new user who was not in
//! the training matrix.
//!
//! ```bash
//! cargo run --release --example serve_recsys
//! ```

use dsanls::algos::DsanlsOptions;
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, DataSource, Job};
use dsanls::rng::Pcg64;
use dsanls::serve::{serve, FactorModel, ServeClient, ServeOptions};

fn main() -> dsanls::Result<()> {
    // --- 1. train on a synthetic low-rank ratings matrix -------------------
    let (users, items, k) = (200usize, 150usize, 8usize);
    let mut rng = Pcg64::new(0x5EC5, 0);
    let m = {
        let u0 = Mat::rand_uniform(users, k, 1.0, &mut rng);
        let v0 = Mat::rand_uniform(items, k, 1.0, &mut rng);
        Matrix::Dense(u0.matmul_nt(&v0))
    };
    let ckpt = std::env::temp_dir().join(format!("serve_recsys_{}.ckpt", std::process::id()));
    let opts = DsanlsOptions {
        nodes: 4,
        rank: k,
        iterations: 60,
        d_u: 50,
        d_v: 40,
        eval_every: 20,
        ..Default::default()
    };
    let out = Job::builder()
        .algorithm(Algo::Dsanls(opts))
        .data(DataSource::Full(&m))
        .checkpoint_every(30, &ckpt)
        .run()?;
    println!("trained: rel-error {:.4}, checkpoint at {}", out.final_error(), ckpt.display());

    // --- 2. load the checkpoint into a serving model ------------------------
    let model = FactorModel::load(&ckpt)?;
    println!(
        "loaded {} users × {} items (k={}, iteration {})",
        model.users(),
        model.items(),
        model.k(),
        model.iteration()
    );

    // --- 3. serve it and query over real TCP --------------------------------
    let mut handle = serve("127.0.0.1:0", model, ServeOptions::default())?;
    println!("serving on {}", handle.addr());
    let mut client = ServeClient::connect(&handle.addr().to_string())?;

    // batched top-k: one GEMM on the server answers all three users
    for (user, recs) in [7u64, 42, 123].iter().zip(client.top_k(&[7, 42, 123], 5)?) {
        let pretty: Vec<String> =
            recs.iter().map(|&(i, s)| format!("{i} ({s:.2})")).collect();
        println!("user {user}: {}", pretty.join(", "));
    }

    // reconstruction: the full predicted-rating row for one user
    let row = client.reconstruct(&[7])?;
    println!(
        "user 7 predicted ratings: {} items, mean {:.3}",
        row.cols(),
        row.data().iter().sum::<f32>() / row.cols() as f32
    );

    // fold-in: a user the model has never seen, embedded from four ratings
    // (served from the LRU cache on repeat queries)
    let ratings: Vec<(u64, f32)> = vec![(3, 5.0), (17, 4.0), (60, 1.0), (149, 3.5)];
    let (embedding, recs) = client.fold_in(&ratings, 5)?;
    println!(
        "new user embedding ({} dims, all ≥ 0: {}):",
        embedding.len(),
        embedding.iter().all(|&v| v >= 0.0)
    );
    let pretty: Vec<String> = recs.iter().map(|&(i, s)| format!("{i} ({s:.2})")).collect();
    println!("new user recommendations: {}", pretty.join(", "));

    println!("\nserver stats: {}", client.stats()?);
    handle.shutdown();
    std::fs::remove_file(&ckpt).ok();
    Ok(())
}
