//! The paper's motivating scenario (Sec. 2.1.2): hospitals A and B hold
//! clinical-record matrices `M₁`, `M₂` over the same phenotypes and want a
//! joint NMF `M = [M₁ M₂] ≈ U·[V₁ᵀ V₂ᵀ]` **without revealing records**.
//!
//! Runs Syn-SSD-UV with the privacy audit enabled, verifies:
//! 1. the joint factorisation beats what either hospital gets alone, and
//! 2. no raw row of `M₁`, `M₂`, `V₁` or `V₂` ever went on the wire.
//!
//! ```bash
//! cargo run --release --example secure_hospitals
//! ```

use dsanls::data::partition::uniform_partition;
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, DataSource, Job};
use dsanls::nmf::{rel_error, Anls, AnlsOptions};
use dsanls::rng::Pcg64;
use dsanls::secure::{AuditLog, AuditVerdict, SecureAlgo, SynOptions};
use dsanls::solvers::SolverKind;

fn main() {
    // Shared phenotype structure: both hospitals' patients express the same
    // 6 latent phenotypes, so the *joint* U is better than per-hospital Us.
    let mut rng = Pcg64::new(77, 0);
    let phenotypes = Mat::rand_uniform(300, 6, 1.0, &mut rng); // U*: tests × phenotypes
    let patients_a = Mat::rand_uniform(120, 6, 1.0, &mut rng); // V₁*
    let patients_b = Mat::rand_uniform(120, 6, 1.0, &mut rng); // V₂*
    let m1 = phenotypes.matmul_nt(&patients_a); // 300×120
    let m2 = phenotypes.matmul_nt(&patients_b);
    let m = Matrix::Dense(Mat::hstack(&[&m1, &m2])); // M = [M₁ M₂], 300×240
    println!("joint records matrix: {}×{} (2 hospitals × 120 patients)", m.rows(), m.cols());

    // --- secure federated factorisation ------------------------------------
    let cols = uniform_partition(240, 2);
    let audit = AuditLog::new();
    let opts = SynOptions {
        nodes: 2,
        rank: 6,
        t1: 30,
        t2: 4,
        solver: SolverKind::ProximalCd,
        d1: 60,
        d2: 40,
        d3: 60,
        eval_every: 0,
        ..Default::default()
    };
    let run = Job::builder()
        .algorithm(Algo::Syn(opts, SecureAlgo::SynSsdUv))
        .data(DataSource::Full(&m))
        .secure_partition(cols)
        .audit(&audit)
        .run()
        .expect("secure job failed");
    println!("Syn-SSD-UV joint error: {:.4}", run.final_error());

    // --- baseline: each hospital factorises alone --------------------------
    let solo = |mx: Mat| {
        Anls::new(AnlsOptions {
            rank: 6,
            iterations: 120,
            solver: SolverKind::Hals,
            inner_sweeps: 2,
            eval_every: 0,
            ..Default::default()
        })
        .run(&Matrix::Dense(mx))
    };
    let fa = solo(m1.clone());
    let fb = solo(m2.clone());
    // evaluate each hospital's *own* reconstruction with the joint factors
    let joint_a = {
        let v1 = run.v.row_block(0..120);
        rel_error(&Matrix::Dense(m1.clone()), &run.u, &v1)
    };
    let joint_b = {
        let v2 = run.v.row_block(120..240);
        rel_error(&Matrix::Dense(m2.clone()), &run.u, &v2)
    };
    println!("hospital A: solo err {:.4} vs joint err {:.4}", fa.final_error(), joint_a);
    println!("hospital B: solo err {:.4} vs joint err {:.4}", fb.final_error(), joint_b);

    // --- privacy audit ------------------------------------------------------
    println!(
        "\naudit: {} payloads, {:.1} KB total on the wire",
        audit.len(),
        audit.bytes() as f64 / 1e3
    );
    // secrets: every patient column (rows of Mᵀ blocks) and V rows
    let secrets = vec![
        (0usize, mat_rows(&m1.transpose())),
        (1usize, mat_rows(&m2.transpose())),
        (0, mat_rows(&run.v.row_block(0..120))),
        (1, mat_rows(&run.v.row_block(120..240))),
    ];
    match audit.verdict(&secrets) {
        AuditVerdict::Clean => println!("audit verdict: CLEAN — no raw record left a hospital"),
        AuditVerdict::Leak { owner, channel } => {
            panic!("PRIVACY VIOLATION: hospital {owner} leaked on {channel}")
        }
    }
    println!("\nsecure_hospitals OK");
}

fn mat_rows(m: &Mat) -> Vec<Vec<f32>> {
    (0..m.rows()).map(|i| m.row(i).to_vec()).collect()
}
